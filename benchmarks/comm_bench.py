"""Communication benchmark over the transport layer -> BENCH_comms.json.

The paper's claim is that the knowledge exchanged per round is ~1.6% of the
raw local data; the transport layer makes that claim BYTE-TRUE (every
ledger entry is the exact encoded frame length) and then pushes below it
with the f16/int8 codecs. This benchmark runs the same multi-round
simulation once per codec (same seed: identical sampling, selections and
LocalUpdates — only the knowledge bytes and the server's decoded metadata
differ) plus the Table-2 upload-everything baseline, and reports per codec:

  * selected-knowledge upload bytes per round (the paper's payload)
  * weight up/down bytes per round (codec-independent, framing-true)
  * knowledge bytes as a fraction of the cohort's raw data bytes
    (the paper's ~1.6%; int8 lands ~4x below raw_f32)
  * final composed-model accuracy — the cost of lossy knowledge is
    OBSERVABLE because the server meta-trains on the decoded payload

Seed-deterministic by construction: every RNG is keyed off fixed seeds.
Writes BENCH_comms.json at the repo root (tracked PR over PR, like
BENCH_selection.json) and returns the CSV rows for benchmarks/run.py
(``--only comms``).
"""
from __future__ import annotations

import os

import numpy as np

from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn
from repro.obs.registry import write_bench
from repro.obs.timing import monotonic

CODECS = ("raw_f32", "f16", "int8")
ROUNDS = 5
NUM_CLIENTS, SAMPLES_PER_CLIENT = 4, 300
PAPER_FRACTION = 0.016          # the claim the codecs push below


def _flcfg(**kw):
    """The learning-capable operating point (mirrors the system test's
    convergent setting at this container's 1-core scale; meta epochs/batch
    are sized for the |D_M| rows that actually cross the wire)."""
    base = dict(num_clients=NUM_CLIENTS, clients_per_round=NUM_CLIENTS,
                local_epochs=2, local_batch_size=50, local_lr=0.1,
                pca_components=24, clusters_per_class=4, kmeans_iters=8,
                meta_epochs=40, meta_batch_size=8, meta_lr=0.05)
    base.update(kw)
    return FLConfig(**base)


def _setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(3000, image_size=cfg.image_size,
                                  num_classes=10, modes_per_class=3,
                                  noise=0.25, seed=0)
    test = SyntheticImageDataset(1000, image_size=cfg.image_size,
                                 num_classes=10, modes_per_class=3,
                                 noise=0.25, seed=1)
    clients = partition_k_shards(train, NUM_CLIENTS, k_classes=3,
                                 samples_per_client=SAMPLES_PER_CLIENT,
                                 seed=0)
    return model, clients, test


def _raw_cohort_bytes(clients):
    """The denominator of the paper's fraction: the cohort's raw local
    data, at its native dtype."""
    return sum(np.asarray(c.data.x).nbytes + np.asarray(c.data.y).nbytes
               for c in clients)


def run():
    model, clients, test = _setting()
    raw_bytes = _raw_cohort_bytes(clients)
    rows, report = [], {"rounds": ROUNDS, "clients": NUM_CLIENTS,
                        "samples_per_client": SAMPLES_PER_CLIENT,
                        "raw_cohort_bytes": raw_bytes,
                        "paper_fraction": PAPER_FRACTION, "codecs": {}}

    for codec in CODECS:
        t0 = monotonic()
        sim = FLSimulation(model, clients, test, _flcfg(
            transport_codec=codec), seed=0)
        res = sim.run(rounds=ROUNDS, eval_every=ROUNDS)
        know = res.comm["up"]["metadata"] / ROUNDS
        upw = res.comm["up"]["weights"] / ROUNDS
        down = res.comm["down"]["weights"] / ROUNDS
        frac = know / raw_bytes
        acc = float(res.test_acc[-1])
        report["codecs"][codec] = {
            "knowledge_up_bytes_per_round": know,
            "weights_up_bytes_per_round": upw,
            "weights_down_bytes_per_round": down,
            "knowledge_fraction_of_raw": frac,
            "final_acc": acc,
            "selected_fraction": float(res.selected_fraction),
            "wall_s": monotonic() - t0,
        }
        rows.append((f"{codec}_knowledge_up_bytes_per_round", know, None))
        rows.append((f"{codec}_knowledge_fraction_of_raw", frac,
                     f"paper claims ~{PAPER_FRACTION}"))
        rows.append((f"{codec}_final_acc", acc, None))

    # Table-2 baseline: every activation map uploaded (1 round is enough
    # for the byte ratio; its trajectory is the tables benchmark's job)
    sim = FLSimulation(model, clients, test, _flcfg(
        use_selection=False, meta_epochs=1), seed=0)
    res = sim.run(rounds=1)
    full = res.comm["up"]["metadata"]
    report["full_metadata_up_bytes_per_round"] = full
    rows.append(("full_metadata_up_bytes_per_round", float(full), None))

    c = report["codecs"]
    ratio = (c["raw_f32"]["knowledge_up_bytes_per_round"]
             / max(c["int8"]["knowledge_up_bytes_per_round"], 1))
    dacc = abs(c["raw_f32"]["final_acc"] - c["int8"]["final_acc"])
    sel_vs_full = (c["raw_f32"]["knowledge_up_bytes_per_round"]
                   / max(report["full_metadata_up_bytes_per_round"], 1))
    report["int8_vs_raw_ratio"] = ratio
    report["int8_acc_delta"] = dacc
    report["selection_vs_full_ratio"] = sel_vs_full
    # NOTE on the absolute fraction: at the reduced split each activation
    # map is ~5.3x its raw sample's bytes and clusters_per_class/|D_k|
    # selects ~4% of samples, so the ABSOLUTE fraction sits above the
    # paper's 1.6% operating point (paper scale: thousands of samples per
    # client -> ~0.8% selected). What the codec controls — and what this
    # bench claims — is the 4x the int8 wire takes OFF whatever fraction
    # the selection knobs produce.
    report["claims"] = {
        "int8_knowledge_geq_3.5x_smaller_than_raw": ratio >= 3.5,
        "int8_final_acc_within_1_point_of_raw": dacc <= 0.01,
        "int8_divides_knowledge_fraction_geq_3.5x":
            c["raw_f32"]["knowledge_fraction_of_raw"]
            >= 3.5 * c["int8"]["knowledge_fraction_of_raw"],
        "selection_beats_full_upload_geq_10x": sel_vs_full <= 0.1,
    }
    rows.append(("int8_vs_raw_knowledge_ratio", ratio, ">=3.5 required"))
    rows.append(("int8_vs_raw_final_acc_delta", dacc, "<=0.01 required"))
    rows.append(("selection_vs_full_upload_ratio", sel_vs_full,
                 "Table 2 comparison"))
    for claim, ok in report["claims"].items():
        rows.append((f"claim_{claim}", "PASS" if ok else "FAIL", None))

    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_comms.json")
    write_bench(out, report)
    return rows, report


if __name__ == "__main__":
    for name, val, extra in run()[0]:
        v = f"{val:.4f}" if isinstance(val, float) else val
        print(f"{name},{v},{extra if extra is not None else ''}")
