"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

  PYTHONPATH=src python -m benchmarks.roofline_report [--dir experiments/dryrun]

Roofline terms are RECOMPUTED from each record's stored HLO cost via
``repro.obs.profile`` (the repo's one cost record + roofline calculator)
rather than read back from the JSON, so the table always reflects the
current peak table; ``model_flops``/``useful_ratio`` are taken from the
stored record (they need the arch config the dry-run had in hand).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.obs import profile

ARCH_ORDER = ["gemma3-4b", "internvl2-26b", "qwen3-moe-30b-a3b",
              "phi3-medium-14b", "llama3.2-1b", "whisper-medium",
              "qwen2-0.5b", "rwkv6-3b", "jamba-1.5-large-398b",
              "deepseek-v2-236b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

MOVE_HINTS = {
    ("memory", "train"): "bf16 attention probs / remat policy saving dots "
                         "cuts recompute+spill traffic",
    ("memory", "prefill"): "fused flash-attention kernel keeps probs in "
                           "VMEM (kernels/flash_attention.py on TPU)",
    ("memory", "decode"): "larger decode batch per chip or quantized (int8) "
                          "KV cache halves HBM streaming",
    ("collective", "train"): "fewer row-parallel psums: shard activations "
                             "on seq, or all-gather weights once per layer",
    ("collective", "prefill"): "overlap layer all-reduce with next matmul "
                               "(async collectives)",
    ("collective", "decode"): "shard_map seq-parallel flash-decode: psum "
                              "softmax stats, not KV/attention tensors",
    ("compute", "train"): "remat policy: save matmul outputs to avoid "
                          "recompute FLOPs",
    ("compute", "prefill"): "skip padded-vocab logits; fuse SwiGLU matmuls",
    ("compute", "decode"): "absorbed MLA / skip reconstructing per-head KV",
}


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if x >= 0.01:
        return f"{x:.2f}"
    return f"{x:.1e}"


def load(dir_, multipod=False, tag=""):
    recs = {}
    for p in glob.glob(os.path.join(dir_, "*.json")):
        r = json.load(open(p))
        if r.get("multi_pod", False) != multipod:
            continue
        if r.get("tag", "") != tag:
            continue
        recs[(r["arch"], r["shape"])] = r
    return recs


def roofline_table(recs):
    peaks = profile.peak_table("tpu")
    lines = ["| arch | shape | compute s | memory s | collective s | bound | "
             "MODEL_FLOPS | useful ratio | what moves the bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                continue
            if r["status"] == "skip":
                lines.append(f"| {a} | {s} | — | — | — | SKIP | — | — | "
                             f"{r['reason']} |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {a} | {s} | — | — | — | ERROR | — | — | "
                             f"{r.get('error','')[:60]} |")
                continue
            rf = r["roofline"]
            terms = profile.roofline(profile.record_from_dryrun(r), peaks,
                                     dtype="bf16")
            hint = MOVE_HINTS.get((terms["bound"], r["kind"]), "")
            lines.append(
                f"| {a} | {s} | {fmt(terms['compute_s'])} | "
                f"{fmt(terms['memory_s'])} | {fmt(terms['collective_s'])} | "
                f"**{terms['bound']}** | {rf['model_flops']:.2e} | "
                f"{rf['useful_ratio']:.2f} | {hint} |")
    return "\n".join(lines)


def dryrun_table(recs):
    lines = ["| arch | shape | chips | params/dev MB | temp MB | "
             "flops/dev | bytes/dev | coll bytes/dev | coll ops |",
             "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None or r["status"] != "ok":
                continue
            mem = r.get("memory") or {}
            arg = mem.get("argument_size_in_bytes", 0) / 1e6
            tmp = mem.get("temp_size_in_bytes", 0) / 1e6
            c = r["cost"]
            co = r["collectives"]
            ops = ", ".join(f"{k}:{v}" for k, v in
                            sorted(co["count_by_kind"].items()))
            lines.append(
                f"| {a} | {s} | {r['chips']} | {arg:.0f} | {tmp:.0f} | "
                f"{c.get('flops_expanded', 0):.2e} | "
                f"{c.get('bytes_expanded', 0):.2e} | "
                f"{co['total_bytes']:.2e} | {ops} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)
    recs = load(args.dir, args.multipod, args.tag)
    print(f"## Roofline ({'multi-pod 512' if args.multipod else 'single-pod 256'}"
          f" chips{', tag=' + args.tag if args.tag else ''})\n")
    print(roofline_table(recs))
    print(f"\n## Dry-run detail\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
