"""Reproductions of the paper's tables at container scale.

The paper's absolute CIFAR-10 numbers need GPUs + the real dataset; offline
we reproduce the STRUCTURE of every experiment on the synthetic clustered
dataset with a reduced WRN, and validate the paper's qualitative claims:

  Table 2/8: upper trained on ALL maps  >>  upper trained on selected maps
  Table 3:   more meta epochs ^ ; smaller batch ^ ; lower lr v
  Table 4:   more clusters ^
  Table 5/6: tiny-subset training from scratch overfits; L2 helps slightly
  Table 7:   L2 on the FL-composed model helps slightly
  + the headline: selected fraction < few %

Each function returns (rows, claims) where claims is a dict of
"paper claim" -> bool validated here. Results land in EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FLConfig, get_wrn_config
from repro.core.compose import evaluate
from repro.core.meta_training import meta_train
from repro.core.selection import select_metadata
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn
from repro.optim import sgd

SEED = 0


def _setting(num_clients=5, samples_per_client=300, rounds=3):
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(3000, image_size=cfg.image_size,
                                  num_classes=10, modes_per_class=3,
                                  noise=0.25, seed=SEED)
    test = SyntheticImageDataset(800, image_size=cfg.image_size,
                                 num_classes=10, modes_per_class=3,
                                 noise=0.25, seed=SEED + 1)
    clients = partition_k_shards(train, num_clients, k_classes=2,
                                 samples_per_client=samples_per_client,
                                 seed=SEED)
    return cfg, model, clients, test, rounds


def _run(model, clients, test, flcfg, rounds):
    sim = FLSimulation(model, clients, test, flcfg, seed=SEED)
    res = sim.run(rounds=rounds, eval_every=rounds)
    return res


BASE = dict(num_clients=5, clients_per_round=5, local_epochs=1,
            local_batch_size=50, local_lr=0.05, pca_components=24,
            kmeans_iters=8, meta_batch_size=20, meta_lr=0.05)


def table_2_and_8_selection_vs_full():
    """with/without metadata selection (paper: 26.68-48.47% vs 70.03%)."""
    cfg, model, clients, test, rounds = _setting()
    rows = []
    res_with = _run(model, clients, test,
                    FLConfig(clusters_per_class=4, meta_epochs=10, **BASE),
                    rounds)
    res_without = _run(model, clients, test,
                       FLConfig(use_selection=False, meta_epochs=10, **BASE),
                       rounds)
    frac = res_with.metadata_counts[-1] / res_with.comm["total_samples"]
    rows.append(("without_selection", res_without.test_acc[-1],
                 res_without.comm["up"]["metadata"]))
    rows.append(("with_selection", res_with.test_acc[-1],
                 res_with.comm["up"]["metadata"]))
    claims = {
        "full-metadata baseline beats selection (Table 2/8 gap)":
            res_without.test_acc[-1] > res_with.test_acc[-1],
        "selection uploads far fewer metadata bytes":
            res_with.comm["up"]["metadata"]
            < 0.2 * res_without.comm["up"]["metadata"],
        "selected fraction is a few % (paper: 0.8%)": frac < 0.05,
    }
    return rows, claims


def table_3_hyperparameters():
    """meta epochs / batch size / lr sweeps (paper Table 3 directions)."""
    cfg, model, clients, test, rounds = _setting()
    rows, accs = [], {}
    for name, kw in [
        ("default(epo=2)", dict(meta_epochs=2)),
        ("epo=30", dict(meta_epochs=30)),
        ("bs=10", dict(meta_epochs=2, meta_batch_size=10)),
        ("lr=0.005", dict(meta_epochs=2, meta_lr=0.005)),
    ]:
        base = dict(BASE, clusters_per_class=4)
        base.update(kw)
        res = _run(model, clients, test, FLConfig(**base), rounds)
        accs[name] = res.test_acc[-1]
        rows.append((name, res.test_acc[-1], None))
    claims = {
        "more meta epochs improves (26.68->39.87 in paper)":
            accs["epo=30"] > accs["default(epo=2)"] - 0.01,
        "smaller meta batch helps (26.68->30.13 in paper)":
            accs["bs=10"] >= accs["default(epo=2)"] - 0.02,
        "much smaller lr hurts (26.68->18.59 in paper)":
            accs["lr=0.005"] <= accs["default(epo=2)"] + 0.02,
    }
    return rows, claims


def table_4_cluster_count():
    cfg, model, clients, test, rounds = _setting()
    rows, accs = [], {}
    for k in (2, 4, 8):
        res = _run(model, clients, test,
                   FLConfig(clusters_per_class=k, meta_epochs=10, **BASE),
                   rounds)
        accs[k] = res.test_acc[-1]
        rows.append((f"clusters={k}", res.test_acc[-1],
                     res.metadata_counts[-1]))
    claims = {"more clusters -> better accuracy (39.87->46.02 in paper)":
              accs[8] > accs[2]}
    return rows, claims


def table_5_6_overfitting_and_l2():
    """Raw WRN trained from scratch on the selected images only (paper's
    ideal-selection control): train acc -> ~100%, test acc plateaus; L2
    gives a marginal improvement."""
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(2000, image_size=cfg.image_size,
                                  num_classes=10, modes_per_class=3,
                                  noise=0.25, seed=SEED)
    test = SyntheticImageDataset(500, image_size=cfg.image_size,
                                 num_classes=10, modes_per_class=3,
                                 noise=0.25, seed=SEED + 1)
    # pretrain briefly on everything (stands in for the 90.79% reference)
    params = model.init(jax.random.PRNGKey(SEED))
    opt = sgd(0.05)
    state = opt.init(params)
    xs = jnp.asarray(train.x)
    ys = jnp.asarray(train.y)
    loss_g = jax.jit(jax.value_and_grad(model.loss))
    for e in range(3):
        perm = np.random.default_rng(e).permutation(len(train.x))[:1000]
        for i in range(0, 1000, 100):
            _, g = loss_g(params, (xs[perm[i:i + 100]], ys[perm[i:i + 100]]))
            params, state = opt.apply(g, state, params)
    pre_acc = evaluate(model, params, test.x, test.y)

    # select representative images via the paper's pipeline (no PCA variant)
    acts = model.apply_lower(params, xs[:1000])
    sel = select_metadata(acts, ys[:1000], jax.random.PRNGKey(1),
                          num_classes=10, clusters_per_class=4,
                          pca_components=24, kmeans_iters=8)
    img = np.asarray(xs)[np.asarray(sel.indices)]
    lbl = np.asarray(ys)[np.asarray(sel.indices)]

    rows, claims = [], {}
    accs = {}
    hist = {}
    for l2 in (0.0, 5e-4):
        p = model.init(jax.random.PRNGKey(2))
        s = opt.init(p)
        from repro.optim import apply_l2
        lg = jax.jit(jax.value_and_grad(
            lambda pp, b: apply_l2(model.loss(pp, b), pp, l2)))
        tr_acc = te_acc = 0.0
        curve = []
        for epoch in range(60):
            _, g = lg(p, (jnp.asarray(img), jnp.asarray(lbl)))
            p, s = opt.apply(g, s, p)
            if (epoch + 1) % 15 == 0:
                tr_acc = evaluate(model, p, img, lbl,
                                  batch_size=min(100, len(img)))
                te_acc = evaluate(model, p, test.x, test.y)
                curve.append((epoch + 1, tr_acc, te_acc))
        accs[l2] = te_acc
        hist[l2] = curve
        rows.append((f"scratch_on_selected l2={l2}", te_acc, tr_acc))
    rows.append(("pretrained_reference", pre_acc, None))
    last = hist[0.0][-1]
    claims = {
        "scratch-on-selected-subset << pretrained (32.6 vs 90.79 in paper)":
            accs[0.0] < pre_acc - 0.05,
        "overfitting: train acc >> test acc on tiny subset (Fig 2)":
            last[1] > last[2] + 0.1,
        "small L2 changes little (+-1 point in paper)":
            abs(accs[5e-4] - accs[0.0]) < 0.15,
    }
    return rows, claims, hist


def table_7_l2_in_fl():
    cfg, model, clients, test, rounds = _setting()
    rows, accs = [], {}
    for l2 in (0.0, 5e-4):
        res = _run(model, clients, test,
                   FLConfig(clusters_per_class=4, meta_epochs=10,
                            meta_l2=l2, **BASE), rounds)
        accs[l2] = res.test_acc[-1]
        rows.append((f"fl_meta l2={l2}", res.test_acc[-1], None))
    claims = {"L2 in FL meta-training: small effect (46->48.5 in paper)":
              abs(accs[5e-4] - accs[0.0]) < 0.2}
    return rows, claims
