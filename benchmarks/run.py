"""Benchmark runner: one entry per paper table + the transport-layer
communication benchmark + kernel micro-benchmarks + the selection-pipeline
suite. Prints ``name,value,extra`` CSV rows and a paper-claim validation
summary; writes experiments/bench_results.json, BENCH_selection.json (the
§3.1 hot-path trajectory), BENCH_comms.json (bytes-per-round + accuracy
per transport codec), BENCH_faults.json (the chaos sweep: graceful
degradation + recovery overhead under injected faults), BENCH_obs.json
(tracing overhead + byte-attribution completeness) and BENCH_service.json
(async service: sync-equivalence, throughput, accuracy-vs-staleness), all
tracked PR over PR. Schemas: docs/benchmarks.md.

  PYTHONPATH=src python -m benchmarks.run \\
      [--only tables|kernels|comms|selection|faults|analysis|obs|service]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.obs.timing import monotonic


def _emit(rows):
    for name, val, extra in rows:
        v = f"{val:.4f}" if isinstance(val, float) else val
        print(f"{name},{v},{extra if extra is not None else ''}")


def run_tables(results):
    import jax
    from benchmarks import paper_tables as T
    t0 = monotonic()
    all_claims = {}

    def section(title, key, fn):
        # clear_caches between sections: the XLA CPU JIT dylib cache can
        # fail ("Failed to materialize symbols") after many executables
        jax.clear_caches()
        print(f"# {title}")
        try:
            out = fn()
        except Exception as e:  # isolate one table's failure
            print(f"{key},ERROR,{type(e).__name__}: {e}")
            return None
        rows, claims = out[0], out[1]
        _emit(rows)
        all_claims.update(claims)
        results[key] = rows
        return out

    section("Table 2/8 — selection vs full metadata", "table_2_8",
            T.table_2_and_8_selection_vs_full)
    section("Table 3 — meta-training hyperparameters", "table_3",
            T.table_3_hyperparameters)
    section("Table 4 — number of clusters", "table_4",
            T.table_4_cluster_count)
    out = section("Table 5/6 + Fig 2 — overfitting on selected subset, L2",
                  "table_5_6", T.table_5_6_overfitting_and_l2)
    if out is not None:
        results["fig2_curves"] = {str(k): v for k, v in out[2].items()}
    section("Table 7 — L2 in FL meta-training", "table_7", T.table_7_l2_in_fl)

    print(f"\n# paper-claim validation ({monotonic()-t0:.0f}s)")
    ok = 0
    for claim, passed in all_claims.items():
        print(f"claim,{'PASS' if passed else 'FAIL'},{claim}")
        ok += bool(passed)
    results["claims"] = {c: bool(p) for c, p in all_claims.items()}
    print(f"claims_passed,{ok}/{len(all_claims)},")
    return all_claims


def run_comm(results):
    """Byte-true communication benchmark over the transport layer: bytes
    per round and final accuracy per codec (raw_f32/f16/int8) plus the
    Table-2 upload-everything baseline -> BENCH_comms.json."""
    from benchmarks import comm_bench as C
    print("# Communication (transport codecs, exact wire bytes) "
          f"-> BENCH_comms.json ({C.NUM_CLIENTS} clients x "
          f"{C.SAMPLES_PER_CLIENT} samples, {C.ROUNDS} rounds/codec)")
    rows, report = C.run()
    _emit(rows)
    results["comms"] = report
    return report


def run_faults(results):
    """Chaos benchmark over the fault-tolerant runtime: accuracy, bytes
    (first transmission vs. retransmit/duplicate overhead) and injected-
    vs-detected corruption counts per (drop, corrupt) rate point
    -> BENCH_faults.json."""
    from benchmarks import chaos_bench as F
    print("# Fault tolerance (deterministic chaos sweep, CRC32 wire) "
          f"-> BENCH_faults.json ({F.NUM_CLIENTS} clients x "
          f"{F.SAMPLES_PER_CLIENT} samples, {F.ROUNDS} rounds/point)")
    rows, report = F.run()
    _emit(rows)
    results["faults"] = report
    return report


def run_service(results):
    """Async service benchmark: sync-equivalence vs FLSimulation,
    throughput (rounds/sec, bytes/sec) and the accuracy-vs-staleness
    curve -> BENCH_service.json."""
    from benchmarks import service_bench as V
    print("# async FL service (degenerate oracle + staleness sweep) "
          f"-> BENCH_service.json ({V.NUM_CLIENTS} clients x "
          f"{V.SAMPLES_PER_CLIENT} samples, {V.ROUNDS} rounds)")
    rows, report = V.run()
    _emit(rows)
    results["service"] = report
    return report


def run_obs(results):
    """Observability benchmark: tracing overhead (traced vs disabled),
    byte-attribution completeness (asserted) and trace throughput
    -> BENCH_obs.json."""
    from benchmarks import obs_bench as O
    print("# observability (tracer overhead + completeness) "
          f"-> BENCH_obs.json ({O.NUM_CLIENTS} clients x "
          f"{O.SAMPLES_PER_CLIENT} samples, {O.ROUNDS} rounds/arm)")
    rows, report = O.run()
    _emit(rows)
    results["obs"] = report
    return report


def run_selection(results):
    """§3.1 selection pipeline at paper scale -> BENCH_selection.json."""
    from benchmarks import selection_bench as S
    print("# selection pipeline (2500 maps, 10x10 clusters; seed vs fused)")
    rows, report = S.run()
    _emit(rows)
    results["selection"] = report
    return report


def run_analysis_bench(results):
    """flcheck wall time: embedded self-test fixtures + the full src/
    scan. The scan must stay under 10 s so the CI gate stays cheap."""
    from repro.analysis import run_analysis
    from repro.analysis.selftest import FIXTURES, run_self_test
    print("# static analysis (flcheck self-test + full src/ scan)")
    t0 = monotonic()
    failures = run_self_test()
    t_self = monotonic() - t0
    t0 = monotonic()
    findings = run_analysis(["src", "benchmarks"])
    t_scan = monotonic() - t0
    rows = [
        ("analysis_selftest_s", t_self,
         f"{len(FIXTURES) - len(failures)}/{len(FIXTURES)} fixtures ok"),
        ("analysis_scan_s", t_scan,
         f"{len(findings)} finding(s), budget 10s"),
        ("analysis_scan_under_budget", float(t_scan < 10.0), "PASS if 1"),
        ("analysis_selftest_ok", float(not failures), "PASS if 1"),
    ]
    _emit(rows)
    results["analysis"] = {"selftest_s": t_self, "scan_s": t_scan,
                           "fixtures": len(FIXTURES),
                           "fixture_failures": failures,
                           "findings": len(findings)}
    return rows


def run_kernels(results):
    from benchmarks import kernel_bench as K
    print("# kernel micro-benchmarks (jnp oracle on CPU + v5e roofline est.)")
    rows = []
    rows += K.bench_kmeans()
    rows += K.bench_selection_pipeline()
    rows += K.bench_attention()
    rows += K.bench_decode()
    _emit([(n, v, e) for n, v, e in rows])
    results["kernels"] = rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "tables", "kernels", "comm", "comms",
                             "selection", "faults", "analysis", "obs",
                             "service"])
    args = ap.parse_args(argv)

    results = {}
    t0 = monotonic()
    if args.only in (None, "selection"):
        run_selection(results)
    if args.only in (None, "comm", "comms"):
        run_comm(results)
    if args.only in (None, "faults"):
        run_faults(results)
    if args.only in (None, "obs"):
        run_obs(results)
    if args.only in (None, "service"):
        run_service(results)
    if args.only in (None, "kernels"):
        run_kernels(results)
    if args.only in (None, "analysis"):
        run_analysis_bench(results)
    claims = {}
    if args.only in (None, "tables"):
        claims = run_tables(results)

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\ntotal,{monotonic()-t0:.1f}s,results->experiments/bench_results.json")
    if claims and not all(claims.values()):
        failed = [c for c, p in claims.items() if not p]
        print(f"WARNING: {len(failed)} claim(s) not validated: {failed}")


if __name__ == "__main__":
    main()
