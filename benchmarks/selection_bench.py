"""Selection-pipeline benchmark (the §3.1 hot path) at paper scale:
one client with 2500 activation maps, 10 classes x 10 clusters.

Compares, on identical data and keys:

  seed            the seed implementation (``select_metadata_reference``:
                  exact eigh PCA + per-class vmapped K-means, full distance
                  matrices re-read through one_hot matmuls, 25 fixed sweeps)
  fused_exact     the fused engine with seed PCA numerics (single-pass
                  label-masked Lloyd + early exit) — selections must be
                  IDENTICAL to seed
  fused_fast      the fused engine with the randomized range-finder PCA —
                  same selections on realistically low-rank maps, no D^3 eigh
  batched(B)      ``select_metadata_batched`` over a stacked cohort,
                  reported per client (the fleet-throughput number)
  chunked(B,c)    the batched cohort STREAMED in client chunks of c
                  (``repro.core.distributed``'s mega-cohort schedule: same
                  selections, one chunk's memory ceiling). Its comparator
                  is the SEQUENTIAL FALLBACK it replaces — past
                  MAX_BATCHED_ELEMENTS the old engine looped clients
                  one at a time — not the one-stack path that cannot run
                  there at all.
  sharded(B)      ``select_metadata_sharded`` over a smoke mesh of host
                  devices (subprocess, XLA_FLAGS device count) — the
                  shard_map pod path, selections identical to batched.
                  Smoke-mesh 'devices' are threads on this container's
                  2 cores, so the measured wall cannot show device
                  parallelism; the entry also reports the measured
                  per-isolated-device cost (1-device mesh) and its /N
                  pod projection.

Activation maps are mode-structured and low-rank (per-class cluster modes on
a decaying spectrum) — the regime the paper's PCA step presumes; white noise
would make selection itself meaningless. Writes BENCH_selection.json (through
the ``repro.obs.registry`` writer, so every run lands in the bench history)
so the perf trajectory of this path is tracked from this PR on.

FLOPs/bytes per path are MEASURED — ``profiled_jit``'s cost record, derived
from the compiled HLO by the repo's one cost deriver
(``launch/hlo_analysis``) — not analytic estimates. The early-exit Lloyd
while-loop has no static trip count, so those records count its body once
and are flagged lower bounds (``cost_is_lower_bound``); utilization rows
divide measured FLOPs by measured wall against the current backend's peak.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import (select_metadata, select_metadata_batched,
                                  select_metadata_reference)
from repro.data import SyntheticActivationMaps
from repro.obs import profile
from repro.obs.registry import write_bench
from repro.obs.timing import timeit

# paper-scale operating point
N, SHAPE, NUM_CLASSES, CLUSTERS = 2500, (16, 16, 4), 10, 10
PCA_P, KMEANS_ITERS, BATCH = 64, 25, 8
CHUNK = 4                                # streaming chunk (clients) to bench
SMOKE_DEVICES = 8                        # host devices for the sharded row


def structured_activations(seed: int):
    """Per-client low-rank mode-structured maps (structure varies per
    client seed — the non-IID setting)."""
    ds = SyntheticActivationMaps(N, SHAPE, num_classes=NUM_CLASSES,
                                 seed=seed, structure_seed=seed)
    return jnp.asarray(ds.x), jnp.asarray(ds.y)


def _time(fn, iters=7):
    """Best-of-``iters`` via the repo's sanctioned timer (warmup included)."""
    return timeit(fn, iters=iters, reduce="min")


def _roofline_v5e(cost):
    """v5e projection of one fused_fast client from the MEASURED cost
    record (same keys as the old analytic estimate, so the trajectory in
    ``bench_history.jsonl`` stays comparable)."""
    tp = profile.peak_table("tpu")
    rf = profile.roofline(cost, tp, dtype="f32")
    return {
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "hlo_unknown_trip_loops": cost.unknown_trip_loops,
        "v5e_compute_us": rf["compute_s"] * 1e6,
        "v5e_hbm_us": rf["memory_s"] * 1e6,
        "v5e_roofline_us": max(rf["compute_s"], rf["memory_s"]) * 1e6,
        "bound": rf["bound"],
    }


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cohort(key):
    cohort = [structured_activations(seed=i) for i in range(BATCH)]
    bacts = jnp.stack([a for a, _ in cohort])
    blabels = jnp.stack([l for _, l in cohort])
    bkeys = jax.random.split(key, BATCH)
    return bacts, blabels, bkeys


def _chunked(bacts, blabels, bkeys, kw):
    """The mega-cohort streaming schedule of distributed.select_cohort at
    the acts level: chunk the client axis, concatenate the selections."""
    from repro.core.selection import Selection
    parts = [select_metadata_batched(bacts[i:i + CHUNK],
                                     blabels[i:i + CHUNK],
                                     bkeys[i:i + CHUNK],
                                     pca_solver="randomized", **kw)
             for i in range(0, BATCH, CHUNK)]
    return Selection(*(jnp.concatenate(fs) for fs in zip(*parts)))


def _indices_md5(sel) -> str:
    import hashlib
    return hashlib.md5(np.asarray(sel.indices).tobytes()).hexdigest()


def _sharded_worker():
    """Subprocess entry (own jax init under forced host device count):
    times ``select_metadata_sharded`` on the same cohort/keys, plus the
    one-device-mesh serial cost (the isolated-per-device number the /N pod
    projection uses) and the one-stack batched path in the same env for a
    like-for-like baseline. Reports the selections' hash for the parent's
    identity check."""
    from repro.core.distributed import (select_metadata_sharded,
                                        selection_mesh)
    key = jax.random.PRNGKey(0)
    kw = dict(num_classes=NUM_CLASSES, clusters_per_class=CLUSTERS,
              pca_components=PCA_P, kmeans_iters=KMEANS_ITERS)
    bacts, blabels, bkeys = _cohort(key)
    mesh = selection_mesh()
    t, s = _time(lambda: select_metadata_sharded(
        bacts, blabels, bkeys, mesh, pca_solver="randomized", **kw), iters=3)
    mesh1 = selection_mesh(1)
    t1, s1 = _time(lambda: select_metadata_sharded(
        bacts, blabels, bkeys, mesh1, pca_solver="randomized", **kw),
        iters=3)
    tb, sb = _time(lambda: select_metadata_batched(
        bacts, blabels, bkeys, pca_solver="randomized", **kw), iters=3)
    print(json.dumps({"wall_s": t, "devices": len(jax.devices()),
                      "one_device_wall_s": t1,
                      "batched_on_mesh_wall_s": tb,
                      "indices_md5": _indices_md5(s),
                      "one_device_md5": _indices_md5(s1),
                      "batched_md5": _indices_md5(sb)}))


def _measure_sharded():
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count="
                         f"{SMOKE_DEVICES}")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), REPO,
                    env.get("PYTHONPATH", "")) if p)
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.selection_bench",
         "--sharded-worker"],
        env=env, capture_output=True, text=True, timeout=1200, cwd=REPO)
    if r.returncode != 0:
        return {"error": r.stderr[-500:]}
    return json.loads(r.stdout.strip().splitlines()[-1])


def run(out_path: str = "BENCH_selection.json"):
    acts, labels = structured_activations(seed=0)
    key = jax.random.PRNGKey(0)
    kw = dict(num_classes=NUM_CLASSES, clusters_per_class=CLUSTERS,
              pca_components=PCA_P, kmeans_iters=KMEANS_ITERS)

    t_seed, s_seed = _time(
        lambda: select_metadata_reference(acts, labels, key, **kw))
    t_exact, s_exact = _time(
        lambda: select_metadata(acts, labels, key, **kw))
    t_fast, s_fast = _time(
        lambda: select_metadata(acts, labels, key,
                                pca_solver="randomized", **kw))

    bacts, blabels, bkeys = _cohort(key)
    t_batch, s_batch = _time(
        lambda: select_metadata_batched(bacts, blabels, bkeys,
                                        pca_solver="randomized", **kw),
        iters=3)
    t_chunk, s_chunk = _time(
        lambda: _chunked(bacts, blabels, bkeys, kw), iters=3)
    sharded = _measure_sharded()

    # measured cost records (HLO-derived; cached per signature, so these
    # reuse what the profiled calls above already compiled)
    cost_exact = select_metadata.cost(acts, labels, key, **kw)
    cost_fast = select_metadata.cost(acts, labels, key,
                                     pca_solver="randomized", **kw)
    cost_batch = select_metadata_batched.cost(bacts, blabels, bkeys,
                                              pca_solver="randomized", **kw)
    peaks = profile.peak_table(jax.default_backend())

    def cost_fields(cost, wall, nclients=1):
        """Measured flops/bytes (per client) + utilization of the measured
        wall against this backend's f32 peak."""
        if cost is None:
            return {}
        return {"flops": cost.flops / nclients,
                "hbm_bytes": cost.hbm_bytes / nclients,
                "utilization": cost.flops / wall / peaks["peak_flops_f32"],
                "cost_is_lower_bound": cost.unknown_trip_loops > 0}

    def match(s):
        return (bool(np.array_equal(np.asarray(s.indices),
                                    np.asarray(s_seed.indices)))
                and bool(np.array_equal(np.asarray(s.valid),
                                        np.asarray(s_seed.valid))))

    def agreement(s):
        """Fraction of cluster slots selecting the same sample as seed.
        fused_exact is 1.0 by construction; fused_fast uses different PCA
        numerics, so its agreement is empirical (1.0 on this fixed draw,
        >=0.99 across draws at this scale) and tracked here per run."""
        return float((np.asarray(s.indices)
                      == np.asarray(s_seed.indices)).mean())

    report = {
        "config": {"n_maps": N, "map_shape": list(SHAPE),
                   "num_classes": NUM_CLASSES,
                   "clusters_per_class": CLUSTERS,
                   "pca_components": PCA_P, "kmeans_iters": KMEANS_ITERS,
                   "batch_clients": BATCH, "backend": jax.default_backend()},
        "paths": {
            "seed": {"wall_s": t_seed},
            "fused_exact": {"wall_s": t_exact,
                            "speedup_vs_seed": t_seed / t_exact,
                            "selections_match_seed": match(s_exact),
                            "selection_agreement": agreement(s_exact),
                            **cost_fields(cost_exact, t_exact)},
            "fused_fast": {"wall_s": t_fast,
                           "speedup_vs_seed": t_seed / t_fast,
                           "selections_match_seed": match(s_fast),
                           "selection_agreement": agreement(s_fast),
                           **cost_fields(cost_fast, t_fast)},
            "batched_per_client": {"wall_s": t_batch / BATCH,
                                   "speedup_vs_seed":
                                       t_seed / (t_batch / BATCH),
                                   **cost_fields(cost_batch, t_batch,
                                                 nclients=BATCH)},
            "chunked_per_client": {
                "wall_s": t_chunk / BATCH,
                "chunk_clients": CHUNK,
                "speedup_vs_seed": t_seed / (t_chunk / BATCH),
                # past MAX_BATCHED_ELEMENTS the old engine fell back to the
                # per-client loop — that loop (one fused_fast client at a
                # time) is what streaming replaces; both ratios jitter
                # ~±20% run-to-run on this shared box (see module docstring)
                "speedup_vs_sequential_fallback": t_fast / (t_chunk / BATCH),
                "throughput_vs_one_stack": t_batch / t_chunk,
                "selections_match_batched": _indices_md5(s_chunk)
                                            == _indices_md5(s_batch)},
            "sharded_per_client": (
                {"error": sharded["error"]} if "error" in sharded else
                {"wall_s": sharded["wall_s"] / BATCH,
                 "devices": sharded["devices"],
                 "batched_on_mesh_wall_s":
                     sharded["batched_on_mesh_wall_s"] / BATCH,
                 "one_device_wall_s_per_client":
                     sharded["one_device_wall_s"] / BATCH,
                 # the smoke mesh's 'devices' are threads sharing this
                 # container's physical cores, so the measured wall cannot
                 # exhibit device parallelism; isolated pod devices each
                 # run the one-device cost, so per-client wall is /N
                 "projected_pod_wall_s_per_client":
                     sharded["one_device_wall_s"]
                     / (BATCH * sharded["devices"]),
                 "projected_pod_speedup_vs_batched":
                     (t_batch / BATCH)
                     / (sharded["one_device_wall_s"]
                        / (BATCH * sharded["devices"])),
                 "speedup_vs_seed":
                     t_seed / (sharded["wall_s"] / BATCH),
                 "selections_match_batched":
                     sharded["indices_md5"] == _indices_md5(s_batch)
                     and sharded["one_device_md5"]
                     == _indices_md5(s_batch)}),
        },
        "roofline_v5e_fused_fast": (
            _roofline_v5e(cost_fast) if cost_fast is not None else
            {"error": "cost extraction failed"}),
    }
    write_bench(out_path, report)

    ff = report["paths"]["fused_fast"]
    rows = [
        ("selection_seed", t_seed * 1e3, "ms"),
        ("selection_fused_exact", t_exact * 1e3,
         f"ms speedup={t_seed/t_exact:.2f}x match={match(s_exact)}"),
        ("selection_fused_fast", t_fast * 1e3,
         f"ms speedup={t_seed/t_fast:.2f}x match={match(s_fast)} "
         f"util={ff.get('utilization', 0):.4f}"),
        ("selection_batched_per_client", t_batch / BATCH * 1e3,
         f"ms speedup={t_seed/(t_batch/BATCH):.2f}x util="
         f"{report['paths']['batched_per_client'].get('utilization', 0):.4f}"),
        ("selection_chunked_per_client", t_chunk / BATCH * 1e3,
         f"ms chunk={CHUNK} "
         f"vs_seq_fallback={t_fast/(t_chunk/BATCH):.2f}x "
         f"match={report['paths']['chunked_per_client']['selections_match_batched']}"),
        ("selection_roofline_v5e_us",
         report["roofline_v5e_fused_fast"].get("v5e_roofline_us", -1.0),
         "measured HLO cost, fused_fast path"),
    ]
    sp = report["paths"]["sharded_per_client"]
    if "error" in sp:
        rows.append(("selection_sharded_per_client", -1.0,
                     f"ERROR {sp['error'][:80]}"))
    else:
        rows.append(
            ("selection_sharded_per_client", sp["wall_s"] * 1e3,
             f"ms devices={sp['devices']} "
             f"pod_projection={sp['projected_pod_wall_s_per_client']*1e3:.0f}ms "
             f"({sp['projected_pod_speedup_vs_batched']:.1f}x batched) "
             f"match={sp['selections_match_batched']}"))
    return rows, report


if __name__ == "__main__":
    if "--sharded-worker" in sys.argv:
        _sharded_worker()
    else:
        rows, _ = run()
        for n, v, e in rows:
            print(f"{n},{v:.4f},{e}")
