"""Selection-pipeline benchmark (the §3.1 hot path) at paper scale:
one client with 2500 activation maps, 10 classes x 10 clusters.

Compares, on identical data and keys:

  seed            the seed implementation (``select_metadata_reference``:
                  exact eigh PCA + per-class vmapped K-means, full distance
                  matrices re-read through one_hot matmuls, 25 fixed sweeps)
  fused_exact     the fused engine with seed PCA numerics (single-pass
                  label-masked Lloyd + early exit) — selections must be
                  IDENTICAL to seed
  fused_fast      the fused engine with the randomized range-finder PCA —
                  same selections on realistically low-rank maps, no D^3 eigh
  batched(B)      ``select_metadata_batched`` over a stacked cohort,
                  reported per client (the fleet-throughput number)

Activation maps are mode-structured and low-rank (per-class cluster modes on
a decaying spectrum) — the regime the paper's PCA step presumes; white noise
would make selection itself meaningless. Writes BENCH_selection.json so the
perf trajectory of this path is tracked from this PR on.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import (select_metadata, select_metadata_batched,
                                  select_metadata_reference)
from repro.data import SyntheticActivationMaps
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

# the selection engine computes in f32; the MXU's f32 throughput is half
# the bf16 peak the mesh constants quote
PEAK_FLOPS_F32 = PEAK_FLOPS_BF16 / 2

# paper-scale operating point
N, SHAPE, NUM_CLASSES, CLUSTERS = 2500, (16, 16, 4), 10, 10
PCA_P, KMEANS_ITERS, BATCH = 64, 25, 8
SKETCH = PCA_P + 32                      # randomized-PCA sketch width


def structured_activations(seed: int):
    """Per-client low-rank mode-structured maps (structure varies per
    client seed — the non-IID setting)."""
    ds = SyntheticActivationMaps(N, SHAPE, num_classes=NUM_CLASSES,
                                 seed=seed, structure_seed=seed)
    return jnp.asarray(ds.x), jnp.asarray(ds.y)


def _time(fn, iters=7):
    out = fn()
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _roofline():
    """Analytic v5e estimate for one fused_fast client: FLOPs of the
    randomized PCA + Lloyd sweeps, HBM bytes of the streamed passes."""
    d = int(np.prod(SHAPE))
    ck = NUM_CLASSES * CLUSTERS
    pca_flops = 10 * N * d * SKETCH              # sketch + power iter + b
    init_flops = 2 * N * PCA_P * CLUSTERS * (CLUSTERS - 1) * NUM_CLASSES
    sweep_flops = 4 * N * PCA_P * ck             # dist + stats per sweep
    flops = pca_flops + init_flops + KMEANS_ITERS * sweep_flops
    xbytes = 5 * N * d * 4                       # PCA passes over the maps
    fbytes = (KMEANS_ITERS + 2) * N * PCA_P * 4  # Lloyd passes over feats
    nbytes = xbytes + fbytes
    return {
        "flops": float(flops),
        "hbm_bytes": float(nbytes),
        "v5e_compute_us": flops / PEAK_FLOPS_F32 * 1e6,
        "v5e_hbm_us": nbytes / HBM_BW * 1e6,
        "v5e_roofline_us": max(flops / PEAK_FLOPS_F32,
                               nbytes / HBM_BW) * 1e6,
    }


def run(out_path: str = "BENCH_selection.json"):
    acts, labels = structured_activations(seed=0)
    key = jax.random.PRNGKey(0)
    kw = dict(num_classes=NUM_CLASSES, clusters_per_class=CLUSTERS,
              pca_components=PCA_P, kmeans_iters=KMEANS_ITERS)

    t_seed, s_seed = _time(
        lambda: select_metadata_reference(acts, labels, key, **kw))
    t_exact, s_exact = _time(
        lambda: select_metadata(acts, labels, key, **kw))
    t_fast, s_fast = _time(
        lambda: select_metadata(acts, labels, key,
                                pca_solver="randomized", **kw))

    cohort = [structured_activations(seed=i) for i in range(BATCH)]
    bacts = jnp.stack([a for a, _ in cohort])
    blabels = jnp.stack([l for _, l in cohort])
    bkeys = jax.random.split(key, BATCH)
    t_batch, _ = _time(
        lambda: select_metadata_batched(bacts, blabels, bkeys,
                                        pca_solver="randomized", **kw),
        iters=3)

    def match(s):
        return (bool(np.array_equal(np.asarray(s.indices),
                                    np.asarray(s_seed.indices)))
                and bool(np.array_equal(np.asarray(s.valid),
                                        np.asarray(s_seed.valid))))

    def agreement(s):
        """Fraction of cluster slots selecting the same sample as seed.
        fused_exact is 1.0 by construction; fused_fast uses different PCA
        numerics, so its agreement is empirical (1.0 on this fixed draw,
        >=0.99 across draws at this scale) and tracked here per run."""
        return float((np.asarray(s.indices)
                      == np.asarray(s_seed.indices)).mean())

    report = {
        "config": {"n_maps": N, "map_shape": list(SHAPE),
                   "num_classes": NUM_CLASSES,
                   "clusters_per_class": CLUSTERS,
                   "pca_components": PCA_P, "kmeans_iters": KMEANS_ITERS,
                   "batch_clients": BATCH, "backend": jax.default_backend()},
        "paths": {
            "seed": {"wall_s": t_seed},
            "fused_exact": {"wall_s": t_exact,
                            "speedup_vs_seed": t_seed / t_exact,
                            "selections_match_seed": match(s_exact),
                            "selection_agreement": agreement(s_exact)},
            "fused_fast": {"wall_s": t_fast,
                           "speedup_vs_seed": t_seed / t_fast,
                           "selections_match_seed": match(s_fast),
                           "selection_agreement": agreement(s_fast)},
            "batched_per_client": {"wall_s": t_batch / BATCH,
                                   "speedup_vs_seed":
                                       t_seed / (t_batch / BATCH)},
        },
        "roofline_v5e_fused_fast": _roofline(),
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)

    rows = [
        ("selection_seed", t_seed * 1e3, "ms"),
        ("selection_fused_exact", t_exact * 1e3,
         f"ms speedup={t_seed/t_exact:.2f}x match={match(s_exact)}"),
        ("selection_fused_fast", t_fast * 1e3,
         f"ms speedup={t_seed/t_fast:.2f}x match={match(s_fast)}"),
        ("selection_batched_per_client", t_batch / BATCH * 1e3,
         f"ms speedup={t_seed/(t_batch/BATCH):.2f}x"),
        ("selection_roofline_v5e_us",
         report["roofline_v5e_fused_fast"]["v5e_roofline_us"],
         "analytic, fused_fast path"),
    ]
    return rows, report
