"""Async FL service benchmark -> BENCH_service.json.

Three measurements over the event-driven server (``repro.fl.service``):

  * sync-equivalence: the degenerate service (DegenerateTraffic,
    buffer == cohort) against the synchronous ``FLSimulation`` on the same
    seed — final weights AND the CommLedger must match bit-for-bit (the
    oracle contract ROADMAP item 1 demands), asserted as claims.
  * throughput under load: ticks/sec, flushes ("rounds")/sec and wire
    bytes/sec for the degenerate run (the apples-to-apples point: same
    work per tick as a simulator round).
  * accuracy-vs-staleness: a Poisson arrival stream with increasing upload
    delays against a small buffer — each point reports the mean/max version
    lag of flushed updates and the final composed-model accuracy, tracing
    how far the FedBuff discount lets accuracy drift as updates age.

Deterministic by construction (fixed FL seed, traffic seeds keyed per
(seed, tick), no fault layer here — chaos_bench owns that axis). Writes
BENCH_service.json at the repo root via ``write_bench`` and returns CSV
rows for benchmarks/run.py (``--only service``).
"""
from __future__ import annotations

import os

import jax
import numpy as np

from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.service import DegenerateTraffic, FLService, PoissonTraffic
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn
from repro.obs.registry import write_bench
from repro.obs.timing import monotonic

ROUNDS = 4
NUM_CLIENTS, SAMPLES_PER_CLIENT = 4, 300
# (delay_ticks, buffer_size, ticks): the staleness sweep — growing upload
# latency against a small buffer makes updates survive more flushes
STALENESS_SWEEP = ((0, 2, 6), (1, 2, 6), (3, 2, 6))
ACC_TOLERANCE = 0.2     # max accuracy drop across the staleness sweep
CHANCE_MARGIN = 1.5     # async points must beat chance by this factor
TRAFFIC_SEED = 0        # seed 3 draws a starved schedule (4 arrivals/6
                        # ticks at rate 2.0) that never exercises staleness


def _flcfg(**kw):
    """comm_bench's learning-capable operating point (same as chaos_bench
    minus the CRC: this bench runs the perfect wire)."""
    base = dict(num_clients=NUM_CLIENTS, clients_per_round=NUM_CLIENTS,
                local_epochs=2, local_batch_size=50, local_lr=0.1,
                pca_components=24, clusters_per_class=4, kmeans_iters=8,
                meta_epochs=40, meta_batch_size=8, meta_lr=0.05)
    base.update(kw)
    return FLConfig(**base)


def _setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(3000, image_size=cfg.image_size,
                                  num_classes=10, modes_per_class=3,
                                  noise=0.25, seed=0)
    test = SyntheticImageDataset(1000, image_size=cfg.image_size,
                                 num_classes=10, modes_per_class=3,
                                 noise=0.25, seed=1)
    clients = partition_k_shards(train, NUM_CLIENTS, k_classes=3,
                                 samples_per_client=SAMPLES_PER_CLIENT,
                                 seed=0)
    return model, clients, test


def _weights_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def run():
    model, clients, test = _setting()
    cfg = _flcfg()
    rows, report = [], {"rounds": ROUNDS, "clients": NUM_CLIENTS,
                        "samples_per_client": SAMPLES_PER_CLIENT,
                        "acc_tolerance": ACC_TOLERANCE}

    # ---- sync-equivalence + throughput (the degenerate point) ----
    sim = FLSimulation(model, clients, test, cfg, seed=0)
    sres = sim.run(rounds=ROUNDS, eval_every=ROUNDS)
    t0 = monotonic()
    svc = FLService(model, clients, test, cfg, seed=0,
                    traffic=DegenerateTraffic())
    vres = svc.run(ticks=ROUNDS, eval_every=ROUNDS)
    wall = monotonic() - t0
    same_w = _weights_equal(sim.server.global_params,
                            svc.server.global_params)
    sim_comm = {k: v for k, v in sres.comm.items() if k != "total_samples"}
    same_l = dict(vres.comm) == sim_comm
    total_bytes = (vres.comm.get("total_up", 0)
                   + vres.comm.get("total_down", 0))
    sync_acc = float(vres.test_acc[-1])
    report["degenerate"] = {
        "weights_bit_identical": same_w,
        "ledger_identical": same_l,
        "final_acc": sync_acc,
        "sim_final_acc": float(sres.test_acc[-1]),
        "mean_staleness": vres.mean_staleness,
        "flushes": vres.flushes,
        "wall_s": wall,
        "rounds_per_sec": vres.flushes / max(wall, 1e-9),
        "ticks_per_sec": vres.ticks / max(wall, 1e-9),
        "bytes_per_sec": total_bytes / max(wall, 1e-9),
        "total_bytes": total_bytes,
    }
    rows.append(("service_rounds_per_sec",
                 report["degenerate"]["rounds_per_sec"], None))
    rows.append(("service_bytes_per_sec",
                 report["degenerate"]["bytes_per_sec"], None))
    rows.append(("service_sync_final_acc", sync_acc, None))

    # ---- accuracy-vs-staleness ----
    report["staleness_curve"] = {}
    for delay, buf, ticks in STALENESS_SWEEP:
        t0 = monotonic()
        s = FLService(model, clients, test, cfg, seed=0,
                      traffic=PoissonTraffic(rate=2.0, seed=TRAFFIC_SEED,
                                             delay_ticks=delay),
                      buffer_size=buf, staleness_alpha=0.5)
        r = s.run(ticks=ticks, eval_every=ticks, drain=True)
        flat = [x for fl in r.flush_staleness for x in fl]
        point = {
            "delay_ticks": delay, "buffer_size": buf, "ticks": ticks,
            "arrivals": int(sum(r.arrivals_per_tick)),
            "flushes": r.flushes,
            "final_acc": float(r.test_acc[-1]) if r.test_acc else 0.0,
            "mean_staleness": r.mean_staleness,
            "max_staleness": int(max(flat)) if flat else 0,
            "wall_s": monotonic() - t0,
        }
        key = f"delay={delay}"
        report["staleness_curve"][key] = point
        rows.append((f"service_{key}_acc", point["final_acc"], None))
        rows.append((f"service_{key}_mean_staleness",
                     point["mean_staleness"], None))

    curve = report["staleness_curve"]
    mild = curve[f"delay={STALENESS_SWEEP[0][0]}"]
    chance = 1.0 / 10  # SyntheticImageDataset num_classes
    report["claims"] = {
        "async_degenerate_matches_sync_weights": same_w,
        "async_degenerate_matches_sync_ledger": same_l,
        "degenerate_run_zero_staleness":
            report["degenerate"]["mean_staleness"] == 0.0,
        "staleness_curve_covers_async_regime": any(
            p["max_staleness"] > 0 for p in curve.values()),
        # the async regime still learns: every sweep point clears chance
        # with margin, and aging updates under the FedBuff discount cost
        # at most ACC_TOLERANCE accuracy vs the zero-delay point
        "async_points_learn_above_chance": all(
            p["final_acc"] >= CHANCE_MARGIN * chance
            for p in curve.values()),
        "staleness_acc_drop_within_tolerance":
            mild["final_acc"] - min(p["final_acc"] for p in curve.values())
            <= ACC_TOLERANCE,
    }
    for claim, ok in report["claims"].items():
        rows.append((f"claim_{claim}", "PASS" if ok else "FAIL", None))

    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_service.json")
    write_bench(out, report)
    return rows, report


if __name__ == "__main__":
    for name, val, extra in run()[0]:
        v = f"{val:.4f}" if isinstance(val, float) else val
        print(f"{name},{v},{extra if extra is not None else ''}")
