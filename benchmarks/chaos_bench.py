"""Chaos benchmark over the fault-tolerant runtime -> BENCH_faults.json.

The robustness claim: under realistic edge failure — clients crashing
mid-round, frames bit-flipped or truncated in flight — the split-FL round
DEGRADES GRACEFULLY instead of diverging or crashing, because (a) every
corrupted frame is DETECTED by the v2 wire's CRC32 and retransmitted under
a bounded budget, and (b) the server aggregates Eq. 2 over exactly the
clients whose frames decoded. This benchmark sweeps (drop_rate,
corruption_rate) over the same seed-deterministic simulation as
benchmarks/comm_bench.py — the (0, 0) point IS the fault-free baseline,
bit-identical to a run with no fault layer at all — and reports per point:

  * final composed-model accuracy vs. the fault-free baseline
  * total upload bytes, split into first-transmission vs. retransmit /
    duplicate overhead (the recovery tax, byte-true in the ledger)
  * injected vs. detected corruption counts: with checksums on, every
    injected corruption must be either detected or harmless-by-luck —
    NEVER silently consumed (silent_corruptions == 0)
  * drops / retransmits / lost frames per round

Seed-deterministic by construction: the fault schedule is keyed off
(fault_seed, round, client, stream), independent of FL randomness.
Writes BENCH_faults.json at the repo root and returns CSV rows for
benchmarks/run.py (``--only faults``).
"""
from __future__ import annotations

import os

import numpy as np

from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.faults import FaultPlan
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn
from repro.obs.registry import write_bench
from repro.obs.timing import monotonic

ROUNDS = 5
NUM_CLIENTS, SAMPLES_PER_CLIENT = 4, 300
# (drop_rate, corruption_rate) sweep; corruption is split between bit-flips
# and truncations. (0, 0) is the fault-free baseline; (0.1, 0.05) is the
# acceptance soak point: accuracy within 0.05 of baseline.
SWEEP = ((0.0, 0.0), (0.1, 0.05), (0.2, 0.1), (0.3, 0.2))
SOAK = (0.1, 0.05)
ACC_TOLERANCE = 0.05


def _flcfg(**kw):
    """comm_bench's learning-capable operating point, with the v2 CRC32
    trailer ON — the zero-silent-acceptance guarantee is the headline."""
    base = dict(num_clients=NUM_CLIENTS, clients_per_round=NUM_CLIENTS,
                local_epochs=2, local_batch_size=50, local_lr=0.1,
                pca_components=24, clusters_per_class=4, kmeans_iters=8,
                meta_epochs=40, meta_batch_size=8, meta_lr=0.05,
                transport_checksum=True)
    base.update(kw)
    return FLConfig(**base)


def _setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(3000, image_size=cfg.image_size,
                                  num_classes=10, modes_per_class=3,
                                  noise=0.25, seed=0)
    test = SyntheticImageDataset(1000, image_size=cfg.image_size,
                                 num_classes=10, modes_per_class=3,
                                 noise=0.25, seed=1)
    clients = partition_k_shards(train, NUM_CLIENTS, k_classes=3,
                                 samples_per_client=SAMPLES_PER_CLIENT,
                                 seed=0)
    return model, clients, test


def _plan(drop: float, corrupt: float) -> FaultPlan:
    """drop splits 2:1 between crash-before-upload and crash-after-select;
    corruption splits 2:1 between bit-flips and truncations."""
    return FaultPlan(drop_rate=drop * 2 / 3, late_crash_rate=drop / 3,
                     bitflip_rate=corrupt * 2 / 3,
                     truncate_rate=corrupt / 3,
                     duplicate_rate=corrupt / 4, max_retries=2)


def run():
    model, clients, test = _setting()
    rows, report = [], {"rounds": ROUNDS, "clients": NUM_CLIENTS,
                        "samples_per_client": SAMPLES_PER_CLIENT,
                        "acc_tolerance": ACC_TOLERANCE, "points": {}}

    base_acc = None
    for drop, corrupt in SWEEP:
        t0 = monotonic()
        plan = _plan(drop, corrupt)
        sim = FLSimulation(model, clients, test, _flcfg(), seed=0,
                           fault_plan=plan if plan.any_faults else None,
                           fault_seed=11,
                           quarantine_after=3, quarantine_cooldown=2)
        res = sim.run(rounds=ROUNDS, eval_every=ROUNDS)
        acc = float(res.test_acc[-1])
        if base_acc is None:
            base_acc = acc
        silent = getattr(sim.channel, "total_silent_corruptions", 0)
        injected = getattr(sim.channel, "total_injected_corruptions", 0)
        first_up = (res.comm["up"].get("metadata", 0)
                    + res.comm["up"].get("weights", 0))
        retx = res.comm["retransmit_up"]
        dup = res.comm["duplicate_up"]
        key = f"drop={drop},corrupt={corrupt}"
        report["points"][key] = {
            "drop_rate": drop, "corruption_rate": corrupt,
            "final_acc": acc, "acc_delta_vs_fault_free": acc - base_acc,
            "first_transmission_up_bytes": first_up,
            "retransmit_up_bytes": retx,
            "duplicate_up_bytes": dup,
            "recovery_overhead_fraction": (retx + dup) / max(first_up, 1),
            "drops_per_round": res.drops,
            "retransmits_per_round": res.retransmits,
            "corruptions_detected_per_round": res.corruptions_detected,
            "quarantined_per_round": res.quarantined,
            "injected_corruptions_total": injected,
            "silent_corruptions_total": silent,
            "wall_s": monotonic() - t0,
        }
        rows.append((f"{key}_final_acc", acc, None))
        rows.append((f"{key}_retransmit_up_bytes", float(retx), None))

    soak = report["points"][f"drop={SOAK[0]},corrupt={SOAK[1]}"]
    every_point_hardened = all(
        p["silent_corruptions_total"] == 0
        and (p["injected_corruptions_total"] == 0
             or sum(p["corruptions_detected_per_round"]) > 0)
        for p in report["points"].values())
    report["claims"] = {
        "soak_acc_within_tolerance_of_fault_free":
            abs(soak["acc_delta_vs_fault_free"]) <= ACC_TOLERANCE,
        "zero_silent_corruptions_with_checksums": every_point_hardened,
        "every_injected_corruption_detected": all(
            sum(p["corruptions_detected_per_round"])
            == p["injected_corruptions_total"]
            for p in report["points"].values()),
        "recovery_overhead_recorded_per_point": all(
            "retransmit_up_bytes" in p for p in report["points"].values()),
        "fault_free_point_charges_no_retransmits":
            report["points"]["drop=0.0,corrupt=0.0"]
            ["retransmit_up_bytes"] == 0,
    }
    rows.append(("soak_acc_delta_vs_fault_free",
                 soak["acc_delta_vs_fault_free"],
                 f"|delta| <= {ACC_TOLERANCE} required"))
    rows.append(("soak_recovery_overhead_fraction",
                 soak["recovery_overhead_fraction"], None))
    for claim, ok in report["claims"].items():
        rows.append((f"claim_{claim}", "PASS" if ok else "FAIL", None))

    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_faults.json")
    write_bench(out, report)
    return rows, report


if __name__ == "__main__":
    for name, val, extra in run()[0]:
        v = f"{val:.4f}" if isinstance(val, float) else val
        print(f"{name},{v},{extra if extra is not None else ''}")
