"""Observability benchmark -> BENCH_obs.json (``run.py --only obs``).

The tracer's contract has three measurable halves, and this bench measures
all of them on the same multi-round simulation:

  * overhead — wall time of a fully-traced run vs the identical disabled
    run (claim: <= 3%; spans are plain-Python appends and the NullTracer
    costs one attribute read, so tracing must never tax the runtime)
  * completeness — every byte the CommLedger charged is attributable to
    some span (``Tracer.attributed_bytes()`` equals the ledger's totals
    and the ``unattributed`` bucket is empty). ASSERTED, not just
    reported: a wire charge outside any span is an instrumentation bug.
  * fidelity — the traced run's final weights and ledger summary are
    bit-identical to the untraced run's (observing the run must not
    change it), plus trace throughput (records/sec) for sizing.

Timing uses the repo clock (``repro.obs.timing``): one warmup run pays
compile, then best-of-``REPS`` per arm — the same discipline as the other
benches, which matters here because the claim is a small ratio.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn
from repro.obs.timing import monotonic

ROUNDS = 3
NUM_CLIENTS, SAMPLES_PER_CLIENT = 3, 150
REPS = 2                      # best-of per arm, after one warmup run
OVERHEAD_CLAIM = 0.03


def _flcfg(**kw):
    base = dict(num_clients=NUM_CLIENTS, clients_per_round=NUM_CLIENTS,
                local_epochs=1, local_batch_size=50, local_lr=0.1,
                pca_components=16, clusters_per_class=3, kmeans_iters=6,
                meta_epochs=10, meta_batch_size=8, meta_lr=0.05)
    base.update(kw)
    return FLConfig(**base)


def _setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(NUM_CLIENTS * SAMPLES_PER_CLIENT,
                                  image_size=cfg.image_size, num_classes=10,
                                  modes_per_class=3, noise=0.25, seed=0)
    test = SyntheticImageDataset(300, image_size=cfg.image_size,
                                 num_classes=10, modes_per_class=3,
                                 noise=0.25, seed=1)
    clients = partition_k_shards(train, NUM_CLIENTS, k_classes=3,
                                 samples_per_client=SAMPLES_PER_CLIENT,
                                 seed=0)
    return model, clients, test


def _run_once(model, clients, test, observability):
    sim = FLSimulation(model, clients, test,
                       _flcfg(observability=observability), seed=0)
    t0 = monotonic()
    res = sim.run(rounds=ROUNDS, eval_every=ROUNDS)
    return sim, res, monotonic() - t0


def _weights_equal(a, b):
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool((np.asarray(x) == np.asarray(y)).all()) for x, y in zip(la, lb))


def run():
    model, clients, test = _setting()
    # one warmup run pays compile for both arms (identical jaxprs: the
    # tracer adds no jax operations — that IS the bit-identity claim)
    _run_once(model, clients, test, False)

    t_off, t_on = float("inf"), float("inf")
    sim_off = sim_on = res_off = res_on = None
    for _ in range(REPS):
        sim_off, res_off, dt = _run_once(model, clients, test, False)
        t_off = min(t_off, dt)
        sim_on, res_on, dt = _run_once(model, clients, test, True)
        t_on = min(t_on, dt)

    overhead = (t_on - t_off) / t_off

    # fidelity: observing the run must not change it
    bit_identical = _weights_equal(sim_off.server.global_params,
                                   sim_on.server.global_params)
    ledger_equal = res_off.comm == res_on.comm
    assert bit_identical, "traced run diverged from untraced weights"
    assert ledger_equal, "traced run diverged from untraced ledger"

    # completeness: every ledger byte reachable from some span
    tr = sim_on.tracer
    att = tr.attributed_bytes()
    att_up = sum(v for k, v in att.items() if k.startswith("up/"))
    att_down = sum(v for k, v in att.items() if k.startswith("down/"))
    led_up = sum(sim_on.server.ledger.up.values())
    led_down = sum(sim_on.server.ledger.down.values())
    assert att_up == led_up and att_down == led_down, (
        f"span-attributed bytes {att_up}/{att_down} != ledger "
        f"{led_up}/{led_down}")
    assert not tr.unattributed, (
        f"bytes charged outside any span: {dict(tr.unattributed)}")

    n_spans, n_events = len(tr.spans), len(tr.events)
    records_per_sec = (n_spans + n_events) / max(t_on, 1e-9)
    sketches = sum(1 for e in tr.events if e["name"] == "selection_sketch")

    report = {
        "rounds": ROUNDS, "clients": NUM_CLIENTS, "reps": REPS,
        "untraced_s": t_off, "traced_s": t_on,
        "overhead_frac": overhead,
        "spans": n_spans, "events": n_events,
        "selection_sketches": sketches,
        "records_per_sec": records_per_sec,
        "attributed_up_bytes": att_up, "attributed_down_bytes": att_down,
        "phase_wall_s": res_on.phase_wall_s,
        "round_wall_s": res_on.round_wall_s,
        "claims": {
            "overhead_leq_3pct": overhead <= OVERHEAD_CLAIM,
            "every_ledger_byte_span_attributed": True,   # asserted above
            "traced_run_bit_identical": bool(bit_identical and ledger_equal),
        },
    }
    rows = [
        ("obs_untraced_s", t_off, None),
        ("obs_traced_s", t_on, None),
        ("obs_overhead_frac", overhead, f"<= {OVERHEAD_CLAIM} claimed"),
        ("obs_trace_records", float(n_spans + n_events),
         f"{n_spans} spans + {n_events} events"),
        ("obs_records_per_sec", records_per_sec, None),
        ("obs_selection_sketches", float(sketches),
         f"{NUM_CLIENTS} clients x {ROUNDS} rounds"),
    ]
    for claim, ok in report["claims"].items():
        rows.append((f"claim_{claim}", "PASS" if ok else "FAIL", None))

    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_obs.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    return rows, report


if __name__ == "__main__":
    for name, val, extra in run()[0]:
        v = f"{val:.4f}" if isinstance(val, float) else val
        print(f"{name},{v},{extra if extra is not None else ''}")
