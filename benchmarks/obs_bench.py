"""Observability benchmark -> BENCH_obs.json (``run.py --only obs``).

The tracer's contract has four measurable halves, and this bench measures
all of them on the same multi-round simulation:

  * overhead — wall time of a fully-traced run vs the identical disabled
    run (claim: <= 3%). Traced/untraced reps run in INTERLEAVED pairs and
    the claim is judged on the median per-pair overhead with its MAD
    spread reported alongside: back-to-back per-arm minima put the two
    arms in different thermal/allocator regimes and once measured the
    "overhead" at -3.7%, i.e. pure noise.
  * completeness — every byte the CommLedger charged is attributable to
    some span (``Tracer.attributed_bytes()`` equals the ledger's totals
    and the ``unattributed`` bucket is empty). ASSERTED, not just
    reported: a wire charge outside any span is an instrumentation bug.
  * fidelity — the traced run's final weights and ledger summary are
    bit-identical to the untraced run's (observing the run must not
    change it).
  * compile discipline — the recompilation sentinel
    (``obs.profile.profiled_jit``): every hot-path compile lands in round
    0 of the first traced run; a compile event whose ancestry reaches a
    ``round > 0`` span is a retrace-per-round bug and fails the bench
    (claim ``zero_hot_path_recompiles_after_round_0``).

Trace throughput (``records_per_sec``) is measured in isolation — a
synthetic span/event storm serialized to a tmpfile — because dividing the
simulation's span count by the whole simulation wall (once ~2 records/s)
says nothing about the tracer; the storm number is what actually bounds
tracer overhead at scale.

Timing uses the repo clock (``repro.obs.timing``); one warmup run per arm
pays jit compiles AND the profiler's one-time per-signature HLO cost
extraction before anything is timed. The report is written through
``repro.obs.registry.write_bench`` (flcheck OBS002), which also appends
the fingerprinted record to ``experiments/bench_history.jsonl`` for
``python -m repro.obs regress``.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro import obs
from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn
from repro.obs.registry import write_bench
from repro.obs.timing import monotonic

ROUNDS = 3
NUM_CLIENTS, SAMPLES_PER_CLIENT = 3, 150
REPS = 3                      # interleaved (untraced, traced) pairs
OVERHEAD_CLAIM = 0.03
STORM_SPANS = 20000           # synthetic records for the throughput probe


def _flcfg(**kw):
    base = dict(num_clients=NUM_CLIENTS, clients_per_round=NUM_CLIENTS,
                local_epochs=1, local_batch_size=50, local_lr=0.1,
                pca_components=16, clusters_per_class=3, kmeans_iters=6,
                meta_epochs=10, meta_batch_size=8, meta_lr=0.05)
    base.update(kw)
    return FLConfig(**base)


def _setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(NUM_CLIENTS * SAMPLES_PER_CLIENT,
                                  image_size=cfg.image_size, num_classes=10,
                                  modes_per_class=3, noise=0.25, seed=0)
    test = SyntheticImageDataset(300, image_size=cfg.image_size,
                                 num_classes=10, modes_per_class=3,
                                 noise=0.25, seed=1)
    clients = partition_k_shards(train, NUM_CLIENTS, k_classes=3,
                                 samples_per_client=SAMPLES_PER_CLIENT,
                                 seed=0)
    return model, clients, test


def _run_once(model, clients, test, observability):
    sim = FLSimulation(model, clients, test,
                       _flcfg(observability=observability), seed=0)
    t0 = monotonic()
    res = sim.run(rounds=ROUNDS, eval_every=ROUNDS)
    return sim, res, monotonic() - t0


def _weights_equal(a, b):
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool((np.asarray(x) == np.asarray(y)).all()) for x, y in zip(la, lb))


def _median(xs):
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def _trace_throughput(n_spans=STORM_SPANS):
    """Isolated tracer throughput: open/close ``n_spans`` spans (one event
    + one byte charge each) and serialize the lot to a tmpfile."""
    tr = obs.Tracer(meta={"synthetic_storm": True})
    t0 = monotonic()
    with obs.use_tracer(tr):
        for i in range(n_spans):
            with obs.span("storm", i=i) as sp:
                obs.event("tick", i=i)
                sp.charge("up", "knowledge", 64, 1)
    fd, path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        tr.write_jsonl(path)
    finally:
        os.unlink(path)
    dt = monotonic() - t0
    n_records = len(tr.spans) + len(tr.events)
    return n_records / max(dt, 1e-9), n_records


def _hot_path_compiles(tr):
    """Partition the trace's sentinel ``compile`` events by the round of
    their enclosing span's ancestry (None = outside any round: setup,
    meta-training warmup, eval)."""
    by_id = {s.span_id: s for s in tr.spans}

    def round_of(ev):
        pid = ev.get("parent")
        while pid is not None and pid in by_id:
            sp = by_id[pid]
            if "round" in sp.attrs:
                return sp.attrs["round"]
            pid = sp.parent_id
        return None

    comp = [e for e in tr.events if e["name"] == "compile"]
    hot = [e for e in comp if (round_of(e) or 0) > 0]
    return comp, hot


def run():
    model, clients, test = _setting()
    # warmups pay jit compiles for both arms (identical jaxprs: the tracer
    # adds no jax operations — that IS the bit-identity claim); the traced
    # warmup additionally pays profiled_jit's one-time per-signature AOT
    # cost extraction, and its cold trace is what the sentinel judges
    _run_once(model, clients, test, False)
    sim_warm, _, _ = _run_once(model, clients, test, True)

    pairs = []
    sim_off = sim_on = res_off = res_on = None
    for _ in range(REPS):
        sim_off, res_off, dt_off = _run_once(model, clients, test, False)
        sim_on, res_on, dt_on = _run_once(model, clients, test, True)
        pairs.append((dt_off, dt_on))
    overheads = [(on - off) / off for off, on in pairs]
    overhead = _median(overheads)
    spread = _median([abs(o - overhead) for o in overheads])   # MAD
    t_off = min(off for off, _ in pairs)
    t_on = min(on for _, on in pairs)

    # fidelity: observing the run must not change it
    bit_identical = _weights_equal(sim_off.server.global_params,
                                   sim_on.server.global_params)
    ledger_equal = res_off.comm == res_on.comm
    assert bit_identical, "traced run diverged from untraced weights"
    assert ledger_equal, "traced run diverged from untraced ledger"

    # completeness: every ledger byte reachable from some span
    tr = sim_on.tracer
    att = tr.attributed_bytes()
    att_up = sum(v for k, v in att.items() if k.startswith("up/"))
    att_down = sum(v for k, v in att.items() if k.startswith("down/"))
    led_up = sum(sim_on.server.ledger.up.values())
    led_down = sum(sim_on.server.ledger.down.values())
    assert att_up == led_up and att_down == led_down, (
        f"span-attributed bytes {att_up}/{att_down} != ledger "
        f"{led_up}/{led_down}")
    assert not tr.unattributed, (
        f"bytes charged outside any span: {dict(tr.unattributed)}")

    # recompilation sentinel: judged on the FIRST traced run (cold
    # signature caches — later reps see every signature already counted)
    compiles, hot_compiles = _hot_path_compiles(sim_warm.tracer)
    assert not hot_compiles, (
        "hot-path recompiles after round 0: "
        + str([(e["attrs"].get("fn"), e["attrs"].get("signature"))
               for e in hot_compiles]))
    compile_counters = {
        k: v for k, v in
        sim_warm.tracer.metrics.snapshot()["counters"].items()
        if k.startswith("compile.") and k.count(".") == 1}

    # cost-annotated spans: the profiled selection call lights up the
    # cohort 'select' span with measured flops + utilization
    select_cost = {}
    for sp in tr.spans:
        if sp.name == "select" and "flops" in sp.attrs:
            select_cost = {
                "flops": sp.attrs["flops"],
                "hbm_bytes": sp.attrs.get("hbm_bytes"),
                "utilization": sp.attrs.get("utilization"),
            }
            break

    n_spans, n_events = len(tr.spans), len(tr.events)
    records_per_sec, storm_records = _trace_throughput()
    sketches = sum(1 for e in tr.events if e["name"] == "selection_sketch")

    report = {
        "rounds": ROUNDS, "clients": NUM_CLIENTS, "reps": REPS,
        "untraced_s": t_off, "traced_s": t_on,
        "overhead_frac": overhead,
        "overhead_spread": spread,
        "overhead_pairs": overheads,
        "spans": n_spans, "events": n_events,
        "selection_sketches": sketches,
        "records_per_sec": records_per_sec,
        "throughput_storm_records": storm_records,
        "attributed_up_bytes": att_up, "attributed_down_bytes": att_down,
        "compile_events_round_0": len(compiles) - len(hot_compiles),
        "compile_counters": compile_counters,
        "select_cost": select_cost,
        "phase_wall_s": res_on.phase_wall_s,
        "round_wall_s": res_on.round_wall_s,
        "claims": {
            "overhead_leq_3pct": overhead <= OVERHEAD_CLAIM,
            "every_ledger_byte_span_attributed": True,   # asserted above
            "traced_run_bit_identical": bool(bit_identical and ledger_equal),
            "zero_hot_path_recompiles_after_round_0": not hot_compiles,
        },
    }
    rows = [
        ("obs_untraced_s", t_off, None),
        ("obs_traced_s", t_on, None),
        ("obs_overhead_frac", overhead,
         f"median of {REPS} pairs, MAD {spread:.4f}, <= "
         f"{OVERHEAD_CLAIM} claimed"),
        ("obs_trace_records", float(n_spans + n_events),
         f"{n_spans} spans + {n_events} events"),
        ("obs_records_per_sec", records_per_sec,
         f"synthetic storm, {storm_records} records"),
        ("obs_selection_sketches", float(sketches),
         f"{NUM_CLIENTS} clients x {ROUNDS} rounds"),
        ("obs_compile_events", float(len(compiles)),
         "all in round 0 / setup (sentinel)"),
    ]
    for claim, ok in report["claims"].items():
        rows.append((f"claim_{claim}", "PASS" if ok else "FAIL", None))

    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_obs.json")
    write_bench(out, report)
    return rows, report


if __name__ == "__main__":
    for name, val, extra in run()[0]:
        v = f"{val:.4f}" if isinstance(val, float) else val
        print(f"{name},{v},{extra if extra is not None else ''}")
