"""Kernel micro-benchmarks: wall time of the jnp oracle paths on CPU (the
Pallas kernels themselves are TPU-target; interpret mode timing is
meaningless, so we bench the reference paths the kernels mirror and report
the analytic FLOPs/bytes each kernel would move on a v5e)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.obs.timing import timeit


def bench_kmeans():
    rows = []
    for n, d, k in [(2500, 200, 10), (2500, 200, 20), (50_000, 200, 10)]:
        x = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)),
                        jnp.float32)
        c = jnp.asarray(np.random.default_rng(1).normal(size=(k, d)),
                        jnp.float32)
        f = jax.jit(ref.kmeans_pairwise_dist_ref)
        dt = timeit(f, x, c).seconds
        flops = 2.0 * n * d * k
        tpu_est = max(flops / PEAK_FLOPS_BF16,
                      (n * d + k * d + n * k) * 4 / HBM_BW)
        rows.append((f"kmeans_dist n={n} d={d} k={k}", dt * 1e6,
                     f"tpu_roofline_us={tpu_est*1e6:.2f}"))
    return rows


def bench_attention():
    rows = []
    for b, s, h, kv, d in [(1, 1024, 8, 4, 64), (1, 2048, 8, 4, 64)]:
        q = jnp.asarray(np.random.default_rng(0).normal(size=(b, s, h, d)),
                        jnp.bfloat16)
        k = jnp.asarray(np.random.default_rng(1).normal(size=(b, s, kv, d)),
                        jnp.bfloat16)
        v = jnp.asarray(np.random.default_rng(2).normal(size=(b, s, kv, d)),
                        jnp.bfloat16)
        f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v,
                                                            causal=True))
        dt = timeit(f, q, k, v, iters=3).seconds
        flops = 4.0 * b * h * s * s * d
        rows.append((f"attn b={b} s={s} h={h} d={d}", dt * 1e6,
                     f"tpu_roofline_us={flops/PEAK_FLOPS_BF16*1e6:.2f}"))
    return rows


def bench_decode():
    rows = []
    for b, s, h, kv, d in [(4, 32_768, 8, 4, 128), (1, 131_072, 8, 4, 128)]:
        q = jnp.asarray(np.random.default_rng(0).normal(size=(b, 1, h, d)),
                        jnp.bfloat16)
        kc = jnp.asarray(np.random.default_rng(1).normal(size=(b, s, kv, d)),
                         jnp.bfloat16)
        vc = jnp.asarray(np.random.default_rng(2).normal(size=(b, s, kv, d)),
                         jnp.bfloat16)
        valid = jnp.ones((b, s), bool)
        f = jax.jit(ref.flash_decode_ref)
        dt = timeit(f, q, kc, vc, valid, iters=3).seconds
        nbytes = 2.0 * b * s * kv * d * 2
        rows.append((f"decode b={b} S={s}", dt * 1e6,
                     f"tpu_hbm_bound_us={nbytes/HBM_BW*1e6:.2f}"))
    return rows


def bench_selection_pipeline():
    """Full §3.1 pipeline at paper scale: 2500 maps/client."""
    from repro.core.selection import select_metadata
    rows = []
    acts = jnp.asarray(np.random.default_rng(0).normal(size=(2500, 16, 16, 4)),
                       jnp.float32)
    labels = jnp.asarray(np.random.default_rng(1).integers(0, 10, 2500))
    key = jax.random.PRNGKey(0)

    def run():
        return select_metadata(acts, labels, key, num_classes=10,
                               clusters_per_class=10, pca_components=64,
                               kmeans_iters=25)
    dt, s = timeit(run, iters=1)
    rows.append(("selection_pipeline_2500maps", dt * 1e6,
                 f"selected={int(np.asarray(s.valid).sum())}"))
    return rows
