"""Baseline (paper-faithful, untagged) vs optimized (tag=opt: flash-VJP,
mask ring-writes, head-aware inference sharding, seq-sharded caches) across
all pairs — the §Perf summary table.

  PYTHONPATH=src python -m benchmarks.opt_compare
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline_report import ARCH_ORDER, SHAPE_ORDER, fmt, load


def main():
    base = load("experiments/dryrun", multipod=False, tag="")
    opt = load("experiments/dryrun", multipod=False, tag="opt")
    print("| arch | shape | baseline bound (s) | optimized bound (s) | step speedup |")
    print("|---|---|---|---|---|")
    gains = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            b, o = base.get((a, s)), opt.get((a, s))
            if not b or not o or b["status"] != "ok" or o["status"] != "ok":
                continue
            tb = max(b["roofline"][k] for k in
                     ("compute_s", "memory_s", "collective_s"))
            to = max(o["roofline"][k] for k in
                     ("compute_s", "memory_s", "collective_s"))
            sp = tb / to if to else float("inf")
            gains.append(sp)
            mark = " **" if sp >= 1.5 else " "
            print(f"| {a} | {s} | {fmt(tb)} ({b['roofline']['bound']}) | "
                  f"{fmt(to)} ({o['roofline']['bound']}) |{mark}{sp:.2f}x |")
    if gains:
        import math
        geo = math.exp(sum(math.log(g) for g in gains) / len(gains))
        print(f"\ngeomean step speedup over {len(gains)} pairs: {geo:.2f}x")


if __name__ == "__main__":
    main()
