#!/usr/bin/env python3
"""Docs link checker (pure stdlib) — run from anywhere, exits non-zero on
any broken reference. CI runs it in the analysis job.

Checks, over README.md and docs/*.md:

* relative markdown links ``[text](target)`` resolve to an existing file
  or directory (http(s)/mailto targets are skipped);
* fragment links into a markdown file (``file.md#anchor`` or ``#anchor``)
  match a real heading, using GitHub's slug rules (lowercase, punctuation
  stripped, spaces to hyphens);
* backtick code references of the form ``path/to/file.py:NN`` name a real
  file with at least NN lines.
"""
from __future__ import annotations

import os
import re
import sys
from typing import List, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
FILE_LINE_RE = re.compile(r"`([A-Za-z0-9_./-]+\.[A-Za-z0-9]+):(\d+)`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
# GitHub slugs keep word chars, hyphens and spaces; everything else drops
SLUG_STRIP_RE = re.compile(r"[^\w\- ]")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    h = re.sub(r"`([^`]*)`", r"\1", heading)      # unwrap inline code
    h = SLUG_STRIP_RE.sub("", h.strip().lower())
    return h.replace(" ", "-")


def heading_slugs(md_path: str) -> List[str]:
    with open(md_path, "r", encoding="utf-8") as f:
        text = f.read()
    # fence-stripped so commented headings inside code blocks don't count
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return [slugify(m.group(1)) for m in HEADING_RE.finditer(text)]


def doc_files() -> List[str]:
    out = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        out += [os.path.join(docs, f) for f in sorted(os.listdir(docs))
                if f.endswith(".md")]
    return [p for p in out if os.path.isfile(p)]


def check_file(path: str) -> List[str]:
    errors: List[str] = []
    rel = os.path.relpath(path, ROOT)
    base = os.path.dirname(path)
    with open(path, "r", encoding="utf-8") as f:
        text = f.read()
    body = re.sub(r"```.*?```", "", text, flags=re.DOTALL)

    for m in LINK_RE.finditer(body):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        fpath, _, frag = target.partition("#")
        tpath = path if not fpath else os.path.normpath(
            os.path.join(base, fpath))
        if not os.path.exists(tpath):
            errors.append(f"{rel}: broken link target {target!r}")
            continue
        if frag and tpath.endswith(".md"):
            if frag not in heading_slugs(tpath):
                errors.append(
                    f"{rel}: anchor #{frag} not found in "
                    f"{os.path.relpath(tpath, ROOT)}")

    for m in FILE_LINE_RE.finditer(body):
        fpath, line = m.group(1), int(m.group(2))
        tpath = os.path.normpath(os.path.join(ROOT, fpath))
        if not os.path.isfile(tpath):
            tpath = os.path.normpath(os.path.join(base, fpath))
        if not os.path.isfile(tpath):
            errors.append(f"{rel}: code reference {m.group(0)} — no such "
                          f"file {fpath!r}")
            continue
        with open(tpath, "r", encoding="utf-8") as f:
            nlines = sum(1 for _ in f)
        if line > nlines:
            errors.append(f"{rel}: code reference {m.group(0)} — "
                          f"{fpath} has only {nlines} lines")
    return errors


def main() -> int:
    files = doc_files()
    errors: List[str] = []
    for p in files:
        errors.extend(check_file(p))
    for e in errors:
        print(f"error: {e}", file=sys.stderr)
    print(f"check_docs: {len(files)} file(s), "
          f"{len(errors)} broken reference(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
