"""Quickstart: the paper's split-FL with clustered data selection, end to end
on CPU in ~2 minutes.

  PYTHONPATH=src python examples/quickstart.py [--trace TRACE.jsonl]

``--trace`` turns on observability (FLConfig.observability) and writes the
run's span/metrics trace as JSONL — inspect it with
``python -m repro.obs summarize TRACE.jsonl``.
"""
import argparse

import jax

from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable observability and write the trace JSONL")
    args = ap.parse_args(argv)
    # 1. the paper's model (reduced WRN for CPU) split after group 1
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    print(f"model: {cfg.name}, split after group {cfg.split_group}")

    # 2. non-IID clients — the paper's §4.1 setting, scaled down:
    #    each client holds samples from just 2 of 10 classes
    train = SyntheticImageDataset(2000, image_size=cfg.image_size,
                                  modes_per_class=3, seed=0)
    test = SyntheticImageDataset(400, image_size=cfg.image_size,
                                 modes_per_class=3, seed=1)
    clients = partition_k_shards(train, num_clients=4, k_classes=2,
                                 samples_per_client=250)
    print(f"clients: {len(clients)}, classes per client: "
          f"{[c.classes.tolist() for c in clients]}")

    # 3. FL config: PCA -> K-means -> 1 representative per cluster (§3.1)
    #    meta_epochs/meta_batch_size are sized for the transport-layer
    #    semantics: the server meta-trains on exactly the |D_M| rows that
    #    crossed the wire (32 here — empty-cluster slots never arrive)
    flcfg = FLConfig(num_clients=4, clients_per_round=4, local_epochs=1,
                     local_batch_size=50, local_lr=0.05,
                     pca_components=24, clusters_per_class=4,
                     meta_epochs=40, meta_batch_size=8, meta_lr=0.05,
                     observability=args.trace is not None)

    # 4. run Algorithm 1 for a few rounds
    sim = FLSimulation(model, clients, test, flcfg, seed=0)
    res = sim.run(rounds=3, eval_every=1, verbose=True)
    if args.trace:
        sim.tracer.write_jsonl(args.trace)
        print(f"trace: {len(sim.tracer.spans)} spans, "
              f"{len(sim.tracer.events)} events -> {args.trace}")

    frac = res.metadata_counts[-1] / res.comm["total_samples"]
    print(f"\nselected metadata fraction: {frac:.2%}  (paper: ~0.8%)")
    print(f"metadata upload: {res.comm['up']['metadata']/1e6:.2f} MB; "
          f"weight upload: {res.comm['up']['weights']/1e6:.2f} MB")
    print(f"final composed-model accuracy: {res.test_acc[-1]:.2%}; "
          f"FedAvg global model: {res.fedavg_acc[-1]:.2%}")


if __name__ == "__main__":
    main()
