"""The paper's technique generalized to a language model: split a tiny
llama-style LM at layer j, run FedAvg on the lower part, select
representative hidden states by PCA+K-means, and meta-train the upper part
on them — all with the same core library the WRN path uses.

  PYTHONPATH=src python examples/federated_lm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FLConfig, get_config
from repro.core import fedavg as fa
from repro.core.meta_training import meta_train
from repro.core.selection import select_metadata
from repro.data import SyntheticTokenDataset, partition_k_shards
from repro.models.transformer import make_split_lm
from repro.optim import sgd


def main():
    cfg = get_config("llama3.2-1b").reduced()
    model, lm = make_split_lm(cfg)
    print(f"LM: {cfg.name} (reduced), split at layer {model.split_layer} "
          f"of {cfg.num_layers}")

    # non-IID clients: per-class bigram token processes
    ds = SyntheticTokenDataset(512, seq_len=32, vocab_size=cfg.vocab_size,
                               num_classes=6)
    clients = partition_k_shards(ds, 4, k_classes=2, samples_per_client=96)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    _, upper0 = model.split(params)
    opt = sgd(0.05)

    for rnd in range(3):
        client_params, metadatas = [], []
        k_round = jax.random.fold_in(key, rnd)
        for ci, c in enumerate(clients):
            toks = jnp.asarray(c.data.x)
            # LocalUpdate (§3.2)
            bs = 16
            steps = len(toks) // bs
            batches = toks[:steps * bs].reshape(steps, bs, -1)
            p, _, losses = fa.local_update(
                params, opt, opt.init(params), (batches,),
                lambda p_, b: model.loss(p_, (b[0],)))
            client_params.append(p)
            # Extract&Selection (§3.1) on mean-pooled split-layer hiddens
            acts = model.apply_lower(params, toks)          # (N, T, d)
            # per-client key: one fold per (round, client) — a shared
            # round key would give every client the same kmeans init
            # stream and correlate their selections (flcheck RNG004)
            sel = select_metadata(acts.mean(1), None,
                                  jax.random.fold_in(k_round, ci),
                                  per_class=False, clusters_per_class=6,
                                  pca_components=16, kmeans_iters=10)
            metadatas.append((jnp.take(acts, sel.indices, 0),
                              jnp.take(toks, sel.indices, 0), sel.valid))
        # server: aggregate metadata, MetaTraining (§3.3)
        acts = jnp.concatenate([m[0] for m in metadatas])
        toks = jnp.concatenate([m[1] for m in metadatas])
        valid = jnp.concatenate([m[2] for m in metadatas])
        upper, meta_losses = meta_train(
            upper0, model.upper_loss, acts, toks, epochs=5, batch_size=8,
            lr=0.05, key=jax.random.fold_in(key, 100 + rnd), valid=valid)
        # compose + FedAvg
        new_global = fa.weight_average(client_params)
        composed = model.merge(model.split(new_global)[0], upper)
        # next-token accuracy of the composed model on held-out data
        test = jnp.asarray(ds.x[:64])
        logits = model.apply(composed, test)
        acc = float((jnp.argmax(logits[:, :-1], -1) == test[:, 1:]).mean())
        frac = float(valid.sum()) / sum(len(c.data) for c in clients)
        print(f"round {rnd}: selected {int(valid.sum())} seqs "
              f"({frac:.1%} of client data), meta loss "
              f"{float(meta_losses[-1]):.3f}, composed next-token acc {acc:.3f}")
        params = new_global
    print("done — the same §3 pipeline, attention-free of the backbone type")


if __name__ == "__main__":
    main()
