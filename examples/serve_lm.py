"""Batched-request serving example: prefill a prompt batch, then jit-decode
with a ring-buffer KV cache (sliding-window layers hold O(window) state).

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --tokens 24
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_decode_step
from repro.models.transformer import LM
from repro.obs.timing import monotonic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    decode_fn, lm = make_decode_step(cfg, dtype=jnp.float32)
    params = lm.init(jax.random.PRNGKey(0))
    cache = lm.init_cache(args.batch, args.cache_len, dtype=jnp.float32)
    if cfg.is_encoder_decoder:
        cache["enc_out"] = jnp.zeros(
            (args.batch, cfg.encoder_seq_len, cfg.d_model))
    jit_decode = jax.jit(decode_fn)

    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, 1)),
                      jnp.int32)
    # warm up / compile
    tok, cache = jit_decode(params, cache, tok)
    t0 = monotonic()
    out = [np.asarray(tok)[:, 0]]
    for _ in range(args.tokens - 1):
        tok, cache = jit_decode(params, cache, tok)
        out.append(np.asarray(tok)[:, 0])
    dt = monotonic() - t0
    gen = np.stack(out, 1)
    print(f"arch={cfg.name} (reduced) batch={args.batch} "
          f"cache={args.cache_len}")
    print(f"{args.tokens} tokens x {args.batch} reqs in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.1f} tok/s on 1 CPU core)")
    for b in range(min(args.batch, 2)):
        print(f"req{b}: {gen[b][:16].tolist()}")


if __name__ == "__main__":
    main()
