"""End-to-end driver: the paper's full experiment grid (Tables 2-8) at
configurable scale — trains the split-FL WRN for a few hundred rounds when
given the budget. This is deliverable (b)'s 'train for a few hundred steps'
driver: every round is a full federated train step over all clients.

  # ~10 min CPU run (reduced scale):
  PYTHONPATH=src python examples/paper_repro.py --rounds 30 --clients 5

  # the paper's full setting (needs real CIFAR-10 + GPUs/TPUs):
  PYTHONPATH=src python examples/paper_repro.py --rounds 100 --clients 20 \
      --samples-per-client 2500 --clusters 20 --full-wrn
"""
import argparse
import json
import os

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn
from repro.obs.timing import monotonic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--samples-per-client", type=int, default=400)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--meta-epochs", type=int, default=10)
    ap.add_argument("--l2", type=float, default=5e-4)
    ap.add_argument("--full-wrn", action="store_true",
                    help="WRN-40-1 at 32x32 (the paper's exact model)")
    ap.add_argument("--no-selection", action="store_true",
                    help="Table 2 baseline: upload ALL activation maps")
    ap.add_argument("--out", default="experiments/paper_repro.json")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_wrn_config() if args.full_wrn else get_wrn_config().reduced()
    model = make_split_wrn(cfg)

    n_train = max(args.clients * args.samples_per_client, 3000)
    train = SyntheticImageDataset(n_train, image_size=cfg.image_size,
                                  modes_per_class=3, seed=0)
    test = SyntheticImageDataset(800, image_size=cfg.image_size,
                                 modes_per_class=3, seed=1)
    clients = partition_k_shards(train, args.clients, k_classes=2,
                                 samples_per_client=args.samples_per_client)

    flcfg = FLConfig(num_clients=args.clients,
                     clients_per_round=args.clients,
                     local_epochs=1, local_batch_size=50, local_lr=0.05,
                     pca_components=24, clusters_per_class=args.clusters,
                     meta_epochs=args.meta_epochs, meta_batch_size=20,
                     meta_lr=0.05, meta_l2=args.l2,
                     use_selection=not args.no_selection)

    sim = FLSimulation(model, clients, test, flcfg, seed=0)
    t0 = monotonic()
    res = sim.run(rounds=args.rounds, eval_every=max(args.rounds // 10, 1),
                  verbose=True)
    if args.ckpt_dir:
        CheckpointManager(args.ckpt_dir).save(
            args.rounds, sim.server.global_params, {"cfg": str(flcfg)})

    out = {
        "config": vars(args),
        "test_acc": res.test_acc,
        "fedavg_acc": res.fedavg_acc,
        "metadata_counts": res.metadata_counts,
        "selected_fraction": res.selected_fraction,
        "comm": {k: v for k, v in res.comm.items()},
        "wall_time_s": monotonic() - t0,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"\nwrote {args.out}; final acc {res.test_acc[-1]:.2%} "
          f"({'no-selection baseline' if args.no_selection else 'with selection'})")


if __name__ == "__main__":
    main()
