"""repro: split-network federated learning with clustered data selection
(Shi & Radu, EuroMLSys 2022) as a production-grade multi-pod JAX framework."""
__version__ = "1.0.0"
