"""Learning-rate schedules as step -> lr callables."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, decay_steps: int, alpha: float = 0.0):
    def f(step):
        t = jnp.clip(step / max(decay_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * ((1 - alpha) * cos + alpha)
    return f


def warmup_cosine(lr: float, warmup_steps: int, decay_steps: int,
                  alpha: float = 0.0):
    cos = cosine_decay(lr, max(decay_steps - warmup_steps, 1), alpha)
    def f(step):
        warm = lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return f


def step_decay(lr: float, boundaries, factor: float = 0.1):
    bs = jnp.asarray(boundaries)
    def f(step):
        k = jnp.sum(step >= bs)
        return lr * factor ** k
    return f
