from repro.optim.optimizers import (Optimizer, sgd, adamw, apply_l2,
                                    global_norm, clip_by_global_norm)
from repro.optim.schedule import (constant, cosine_decay, warmup_cosine,
                                  step_decay)

__all__ = ["Optimizer", "sgd", "adamw", "apply_l2", "global_norm",
           "clip_by_global_norm", "constant", "cosine_decay",
           "warmup_cosine", "step_decay"]
