"""Minimal optax-style optimizers as pure pytree transforms.

The paper trains with SGD (lr 0.1) and studies L2 regularization
(Tables 6/7); AdamW is provided for the LM substrate. Everything is a pair
of pure functions so it composes with vmap (stacked FL clients), shard_map
and lax.scan (local-update loops).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple]       # (grads, state, params, step|None) -> (updates, state)

    def apply(self, grads, state, params, step=None):
        updates, state = self.update(grads, state, params, step)
        new_params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
        return new_params, state


def _as_lr(lr, step):
    return lr(step) if callable(lr) else lr


def sgd(lr, momentum: float = 0.0, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    """SGD with optional (decoupled) weight decay == the paper's L2 term."""

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, step=None):
        lr_t = _as_lr(lr, step)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p.astype(g.dtype),
                                 grads, params)
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr_t * g, grads), ()
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -(lr_t * (momentum * m + g)), new_m, grads)
        else:
            upd = jax.tree.map(lambda m: -lr_t * m, new_m)
        return upd, new_m


    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jnp.ndarray


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return AdamState(jax.tree.map(jnp.zeros_like, params),
                         jax.tree.map(jnp.zeros_like, params),
                         jnp.zeros((), jnp.int32))

    def update(grads, state, params, step=None):
        count = state.count + 1
        lr_t = _as_lr(lr, count if step is None else step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def u(m, v, p):
            step_ = m / c1 / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(step_.dtype)
            return -lr_t * step_

        return jax.tree.map(u, mu, nu, params), AdamState(mu, nu, count)

    return Optimizer(init, update)


def apply_l2(loss: jnp.ndarray, params: PyTree, l2: float) -> jnp.ndarray:
    """Explicit L2 penalty added to the loss (paper Tables 6/7 formulation)."""
    if not l2:
        return loss
    sq = sum(jnp.sum(jnp.square(p.astype(jnp.float32)))
             for p in jax.tree.leaves(params))
    return loss + l2 * sq


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
