"""Datasets. The container is offline, so CIFAR-10 is replaced by a synthetic
class-structured image dataset whose clustering structure makes the paper's
selection mechanism meaningful: each class is a mixture of ``modes_per_class``
Gaussian prototype images plus per-sample noise and random shifts, so
(a) per-class K-means finds real modes, and (b) a representative-per-mode
subset genuinely summarizes a client's data. See DESIGN.md §2.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Dataset:
    x: np.ndarray          # images (N,H,W,C) float32 or tokens (N,T) int32
    y: np.ndarray          # labels (N,) int32
    num_classes: int

    def __len__(self):
        return len(self.x)

    def subset(self, idx) -> "Dataset":
        return Dataset(self.x[idx], self.y[idx], self.num_classes)


def SyntheticImageDataset(num_samples: int = 10_000, image_size: int = 32,
                          channels: int = 3, num_classes: int = 10,
                          modes_per_class: int = 4, noise: float = 0.35,
                          seed: int = 0, structure_seed: int = 0) -> Dataset:
    """CIFAR-10 stand-in with explicit intra-class cluster structure.

    ``seed`` draws the samples; ``structure_seed`` draws the class/mode
    prototypes. They are separate so differently-seeded datasets (e.g. a
    train and a test split) describe the SAME classes — real datasets'
    classes do not change between splits."""
    srng = np.random.default_rng(structure_seed)
    rng = np.random.default_rng(seed)
    # low-frequency prototypes: random coefficients on a coarse grid, upsampled
    coarse = max(4, image_size // 4)
    protos = srng.normal(0, 1.0, (num_classes, modes_per_class, coarse, coarse, channels))
    protos = protos.repeat(image_size // coarse, axis=2).repeat(image_size // coarse, axis=3)
    y = rng.integers(0, num_classes, num_samples).astype(np.int32)
    modes = rng.integers(0, modes_per_class, num_samples)
    x = protos[y, modes].astype(np.float32)
    # nuisance: per-sample circular shift + pixel noise
    shifts = rng.integers(-2, 3, (num_samples, 2))
    for i in range(num_samples):
        x[i] = np.roll(x[i], tuple(shifts[i]), axis=(0, 1))
    x += rng.normal(0, noise, x.shape).astype(np.float32)
    # normalise roughly like CIFAR preprocessing
    x = (x - x.mean()) / (x.std() + 1e-6)
    return Dataset(x.astype(np.float32), y, num_classes)


def SyntheticActivationMaps(num_samples: int = 2500,
                            map_shape: tuple = (16, 16, 4),
                            num_classes: int = 10, modes_per_class: int = 4,
                            rank: int = 96, spectrum_decay: float = 0.9,
                            jitter: float = 0.3, noise: float = 0.01,
                            seed: int = 0, structure_seed: int = 0) -> Dataset:
    """Split-layer activation-map stand-in: per-class latent cluster modes
    pushed through a decaying-spectrum linear map plus a little isotropic
    noise — low-rank, mode-structured, the regime the paper's §3.1
    PCA + per-class K-means presumes (white noise would make selection
    meaningless). Shared by the selection benchmark and the identity
    tests so both validate the same data regime."""
    d = int(np.prod(map_shape))
    srng = np.random.default_rng(structure_seed)
    rng = np.random.default_rng(seed)
    spectrum = 3.0 * spectrum_decay ** np.arange(rank)
    w = srng.normal(size=(rank, d)).astype(np.float32) * spectrum[:, None]
    mode_z = srng.normal(
        size=(num_classes, modes_per_class, rank)).astype(np.float32) * 2.0
    y = rng.integers(0, num_classes, num_samples).astype(np.int32)
    modes = rng.integers(0, modes_per_class, num_samples)
    z = (mode_z[y, modes]
         + jitter * rng.normal(size=(num_samples, rank)).astype(np.float32))
    x = z @ w + noise * rng.normal(size=(num_samples, d)).astype(np.float32)
    return Dataset(x.reshape((num_samples,) + map_shape), y, num_classes)


def SyntheticTokenDataset(num_samples: int = 2048, seq_len: int = 128,
                          vocab_size: int = 512, num_classes: int = 8,
                          seed: int = 0, structure_seed: int = 0) -> Dataset:
    """Token sequences drawn from per-class bigram processes (so hidden states
    at the split layer cluster by class, mirroring the paper's setting for the
    LM generalization). ``structure_seed`` fixes the per-class processes
    independently of the sampling ``seed`` (see SyntheticImageDataset)."""
    srng = np.random.default_rng(structure_seed)
    rng = np.random.default_rng(seed)
    # per-class sparse bigram transition tables
    tables = srng.dirichlet(np.ones(vocab_size) * 0.05, (num_classes, vocab_size))
    y = rng.integers(0, num_classes, num_samples).astype(np.int32)
    x = np.zeros((num_samples, seq_len), np.int32)
    x[:, 0] = rng.integers(0, vocab_size, num_samples)
    u = rng.random((num_samples, seq_len))
    for t in range(1, seq_len):
        cdf = np.cumsum(tables[y, x[:, t - 1]], axis=-1)
        x[:, t] = (u[:, t, None] > cdf).sum(-1).clip(0, vocab_size - 1)
    return Dataset(x, y, num_classes)
