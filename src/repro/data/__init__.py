from repro.data.datasets import (SyntheticActivationMaps,
                                 SyntheticImageDataset, SyntheticTokenDataset,
                                 Dataset)
from repro.data.partition import (partition_k_shards, partition_dirichlet,
                                  ClientData)
from repro.data.pipeline import BatchIterator, batched_epoch

__all__ = ["Dataset", "SyntheticActivationMaps", "SyntheticImageDataset",
           "SyntheticTokenDataset", "partition_k_shards",
           "partition_dirichlet", "ClientData", "BatchIterator",
           "batched_epoch"]
