from repro.data.datasets import (SyntheticImageDataset, SyntheticTokenDataset,
                                 Dataset)
from repro.data.partition import (partition_k_shards, partition_dirichlet,
                                  ClientData)
from repro.data.pipeline import BatchIterator, batched_epoch

__all__ = ["Dataset", "SyntheticImageDataset", "SyntheticTokenDataset",
           "partition_k_shards", "partition_dirichlet", "ClientData",
           "BatchIterator", "batched_epoch"]
