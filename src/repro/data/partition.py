"""Non-IID client partitioners.

The paper (§4.1): 20 clients, each holding 2500 images drawn from just TWO
random classes of CIFAR-10 — that is ``partition_k_shards(k_classes=2)``.
``partition_dirichlet`` is the standard alternative (label skew via Dir(alpha)).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.data.datasets import Dataset


@dataclass
class ClientData:
    client_id: int
    data: Dataset
    classes: np.ndarray    # classes present on this client


def partition_k_shards(ds: Dataset, num_clients: int, k_classes: int = 2,
                       samples_per_client: int = 0, seed: int = 0
                       ) -> List[ClientData]:
    """Each client receives ``samples_per_client`` samples from ``k_classes``
    randomly chosen classes (paper: 20 clients x 2500 images x 2 classes)."""
    rng = np.random.default_rng(seed)
    by_class = {c: list(rng.permutation(np.where(ds.y == c)[0]))
                for c in range(ds.num_classes)}
    present = np.unique(ds.y)          # tiny datasets may miss some classes
    clients = []
    for cid in range(num_clients):
        classes = rng.choice(present, size=min(k_classes, len(present)),
                             replace=False)
        want = samples_per_client or (len(ds) // num_clients)
        per_class = want // k_classes
        idx = []
        for c in classes:
            pool = by_class[int(c)]
            take = pool[:per_class]
            # recycle indices if a class pool runs dry (paper samples "randomly")
            if len(take) < per_class:
                src = np.where(ds.y == c)[0]   # non-empty: c drawn from present
                extra = rng.choice(src, per_class - len(take), replace=True)
                take = take + list(extra)
            by_class[int(c)] = pool[per_class:]
            idx.extend(take)
        idx = np.asarray(idx, np.int64)
        clients.append(ClientData(cid, ds.subset(idx), np.sort(classes)))
    return clients


def partition_dirichlet(ds: Dataset, num_clients: int, alpha: float = 0.5,
                        seed: int = 0) -> List[ClientData]:
    rng = np.random.default_rng(seed)
    idx_by_client = [[] for _ in range(num_clients)]
    for c in range(ds.num_classes):
        idx = rng.permutation(np.where(ds.y == c)[0])
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for cid, part in enumerate(np.split(idx, cuts)):
            idx_by_client[cid].extend(part)
    out = []
    for cid, idx in enumerate(idx_by_client):
        idx = np.asarray(idx, np.int64)
        sub = ds.subset(idx) if len(idx) else Dataset(
            ds.x[:0], ds.y[:0], ds.num_classes)
        out.append(ClientData(cid, sub, np.unique(sub.y)))
    return out
