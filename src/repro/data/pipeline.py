"""Batching pipeline: deterministic shuffled epochs, drop-remainder batching,
and a stateful iterator usable inside the FL simulator's local-update loop.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.data.datasets import Dataset


def batched_epoch(ds: Dataset, batch_size: int, seed: int = 0,
                  drop_remainder: bool = True
                  ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    order = np.random.default_rng(seed).permutation(len(ds))
    n = (len(ds) // batch_size * batch_size) if drop_remainder else len(ds)
    for s in range(0, max(n, batch_size if not drop_remainder else 0), batch_size):
        idx = order[s:s + batch_size]
        if len(idx) == 0:
            break
        yield ds.x[idx], ds.y[idx]


class BatchIterator:
    """Endless epoch-shuffled batches; tracks epoch/step for checkpoint resume."""

    def __init__(self, ds: Dataset, batch_size: int, seed: int = 0):
        if len(ds) < batch_size:
            # small clients: sample with replacement up to a full batch
            rng = np.random.default_rng(seed)
            idx = rng.choice(len(ds), batch_size, replace=True)
            ds = ds.subset(idx)
        self.ds, self.batch_size, self.seed = ds, batch_size, seed
        self.epoch, self._iter = 0, None

    def __iter__(self):
        return self

    def __next__(self):
        if self._iter is None:
            self._iter = batched_epoch(self.ds, self.batch_size,
                                       self.seed + self.epoch)
        try:
            return next(self._iter)
        except StopIteration:
            self.epoch += 1
            self._iter = batched_epoch(self.ds, self.batch_size,
                                       self.seed + self.epoch)
            return next(self._iter)
