import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("_REPRO_DRYRUN_DEVICES", "512")
                           ).strip()
# ^ MUST precede any jax import: jax locks the device count on first init.

# Multi-pod dry-run: .lower().compile() every (architecture x input shape)
# on the production mesh; report memory_analysis / cost_analysis / collective
# schedule -> EXPERIMENTS.md §Dry-run and §Roofline.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--out experiments/dryrun]
#   ... --smoke   (tiny mesh + reduced configs: the CI path)

import argparse
import dataclasses
import json
import sys
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, INPUT_SHAPES, TrainConfig, get_config
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import parse_hlo
from repro.launch.specs import input_specs
from repro.obs import profile
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models.registry import count_params
from repro.obs.timing import monotonic


def resolve_mode(cfg, shape_name: str):
    """(runnable?, force_swa, reason) — DESIGN.md §5 long_500k policy."""
    if shape_name != "long_500k":
        return True, False, ""
    mode = cfg.long_context_mode
    if mode == "skip":
        return False, False, f"{cfg.name}: long_500k outside family envelope"
    if mode in ("native", "state"):
        return True, False, ""
    return True, True, "swa-variant"   # dense archs: sliding-window variant


def build(cfg, shape, mesh, tcfg: TrainConfig, cache_seq_shard=False):
    _, force_swa, _ = resolve_mode(cfg, shape.name)
    if shape.kind == "train":
        step, lm = make_train_step(cfg, tcfg)
    elif shape.kind == "prefill":
        step, lm = make_prefill_step(cfg, force_swa=force_swa)
    else:
        step, lm = make_decode_step(cfg, force_swa=force_swa)
    specs = input_specs(cfg, shape, mesh, tcfg, force_swa=force_swa, lm=lm,
                        cache_seq_shard=cache_seq_shard)
    out_shardings = None
    if specs["mode"] == "train":
        args = (specs["params"], specs["opt_state"], specs["batch"],
                specs["key"])
        # round output = next round's client params: same sharding as input
        pshard = jax.tree.map(lambda s: s.sharding, specs["params"],
                              is_leaf=lambda x: isinstance(
                                  x, jax.ShapeDtypeStruct))
        out_shardings = (pshard, (), None)
    elif specs["mode"] == "prefill":
        args = (specs["params"], specs["batch"])
    else:
        args = (specs["params"], specs["cache"], specs["tokens"])
        cshard = jax.tree.map(lambda s: s.sharding, specs["cache"],
                              is_leaf=lambda x: isinstance(
                                  x, jax.ShapeDtypeStruct))
        out_shardings = (None, cshard)
    return step, args, specs, out_shardings


def run_one(arch: str, shape_name: str, *, multi_pod=False, smoke=False,
            tcfg: TrainConfig = None, save_dir=None, tag="",
            mla_absorbed=False, cache_seq_shard=False, verbose=True):
    tcfg = tcfg or TrainConfig()
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    if mla_absorbed:
        cfg = dataclasses.replace(cfg, mla_absorbed=True)
    shape = INPUT_SHAPES[shape_name]
    if smoke:
        shape = dataclasses.replace(
            shape, seq_len=min(shape.seq_len, 128),
            global_batch=min(shape.global_batch, 8))
    ok, force_swa, reason = resolve_mode(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
           "tag": tag, "status": "skip", "reason": reason}
    if not ok:
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {reason}")
        return rec

    mesh = (mesh_lib.make_smoke_mesh(multi_pod=multi_pod) if smoke
            else mesh_lib.make_production_mesh(multi_pod=multi_pod))
    nchips = mesh.devices.size
    t0 = monotonic()
    try:
        step, args, specs, out_shardings = build(
            cfg, shape, mesh, tcfg, cache_seq_shard=cache_seq_shard)
        with mesh:
            jitted = (jax.jit(step, out_shardings=out_shardings)
                      if out_shardings is not None else jax.jit(step))
            lowered = jitted.lower(*args)
            t_lower = monotonic() - t0
            compiled = lowered.compile()
            t_compile = monotonic() - t0 - t_lower

        mem = None
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                mem = {k: getattr(ma, k) for k in
                       ("argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes")
                       if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not support it
            mem = {"error": str(e)}

        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            cost = {k: float(v) for k, v in ca.items()
                    if isinstance(v, (int, float)) and
                    k in ("flops", "bytes accessed", "transcendentals",
                          "optimal_seconds")}
        except Exception as e:
            cost = {"error": str(e)}

        hlo = compiled.as_text()
        hc = parse_hlo(hlo)
        crec = profile.record_from_hlo(hc)
        coll = {"total_bytes": crec.collective_bytes,
                "bytes_by_kind": dict(hc.coll_bytes),
                "count_by_kind": dict(hc.coll_count),
                "unknown_trip_counts": crec.unknown_trip_loops}
        # trip-count-expanded per-device totals (see hlo_analysis.py —
        # compiled.cost_analysis() does NOT expand while loops on CPU)
        cost["flops_expanded"] = crec.flops
        cost["bytes_expanded"] = crec.hbm_bytes

        n_params = count_params(cfg)
        n_active = count_params(cfg, active_only=True)
        n_nonembed = count_params(cfg, active_only=True, include_embed=False)
        rec.update(
            status="ok", chips=nchips, force_swa=force_swa,
            seq_len=shape.seq_len, global_batch=shape.global_batch,
            kind=shape.kind, t_lower_s=round(t_lower, 1),
            t_compile_s=round(t_compile, 1),
            params=n_params, active_params=n_active,
            nonembed_active_params=n_nonembed,
            memory=mem, cost=cost, collectives=coll,
            hlo_bytes=len(hlo))
        rec["roofline"] = roofline_terms(rec, tcfg)
        if verbose:
            r = rec["roofline"]
            print(f"[ok] {arch} x {shape_name}{' MP' if multi_pod else ''}"
                  f"{(' ' + tag) if tag else ''}: "
                  f"compute {r['compute_s']:.2e}s  memory {r['memory_s']:.2e}s"
                  f"  collective {r['collective_s']:.2e}s  -> {r['bound']}"
                  f"  (lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[ERR] {arch} x {shape_name}: {type(e).__name__}: {e}")

    if save_dir:
        os.makedirs(save_dir, exist_ok=True)
        suffix = ("_mp" if multi_pod else "") + (f"_{tag}" if tag else "")
        path = os.path.join(save_dir,
                            f"{arch.replace('.', '_')}_{shape_name}{suffix}.json")
        slim = {k: v for k, v in rec.items() if k != "trace"}
        with open(path, "w") as f:
            json.dump(slim, f, indent=1, default=str)
    return rec


def roofline_terms(rec: dict, tcfg: TrainConfig) -> dict:
    """The three roofline terms (per brief) from per-device HLO numbers,
    via the one roofline calculator (``repro.obs.profile.roofline``)."""
    chips = rec["chips"]
    crec = profile.record_from_dryrun(rec)
    flops_dev = crec.flops
    terms = profile.roofline(crec, profile.peak_table("tpu"), dtype="bf16")
    # MODEL_FLOPS: 6*N_active*D train (D = tokens this step), 2*N*D decode
    toks = rec["global_batch"] * (rec["seq_len"] if rec["kind"] != "decode"
                                  else 1)
    n = rec["nonembed_active_params"]
    if rec["kind"] == "train":
        toks_total = toks * tcfg.local_steps * (1 + tcfg.meta_steps * 0)
        model_flops = 6 * n * toks_total
    elif rec["kind"] == "prefill":
        model_flops = 2 * n * toks
    else:
        model_flops = 2 * n * toks
    hlo_total = flops_dev * chips
    terms.update(model_flops=model_flops, hlo_flops_total=hlo_total,
                 useful_ratio=(model_flops / hlo_total) if hlo_total else 0.0)
    return terms


PAIRS = [(a, s) for a in ARCHS for s in INPUT_SHAPES]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--local-steps", type=int, default=None)
    ap.add_argument("--no-split-fl", action="store_true")
    ap.add_argument("--seq-shard-acts", action="store_true",
                    help="H1: shard hidden states on seq over 'model'")
    ap.add_argument("--cache-seq-shard", action="store_true",
                    help="H2: shard decode KV cache on seq over 'model'")
    ap.add_argument("--fedavg-bf16", action="store_true",
                    help="H3: bf16 delta all-reduce for FedAvg")
    args = ap.parse_args(argv)

    tkw = {}
    if args.local_steps is not None:
        tkw["local_steps"] = args.local_steps
    if args.no_split_fl:
        tkw["split_fl"] = False
    if args.seq_shard_acts:
        tkw["seq_shard_activations"] = True
    if args.fedavg_bf16:
        tkw["fedavg_compress"] = "bf16"
    tcfg = TrainConfig(**tkw)

    pairs = PAIRS if args.all else [(args.arch or "llama3.2-1b",
                                     args.shape or "train_4k")]
    results = []
    for arch, shape in pairs:
        results.append(run_one(arch, shape, multi_pod=args.multipod,
                               smoke=args.smoke, tcfg=tcfg,
                               save_dir=args.out, tag=args.tag,
                               mla_absorbed=args.mla_absorbed,
                               cache_seq_shard=args.cache_seq_shard))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = len(results) - n_ok - n_skip
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip, {n_err} error "
          f"of {len(results)}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
