"""Lowered step functions for the pod runtime.

train_step  — ONE federated round as ONE pjit step (the paper's Algorithm 1
              mapped onto the mesh, DESIGN.md §4):
                * params carry a leading cohort axis G sharded over the
                  federated mesh axes (data and/or pod);
                * each cohort runs L local SGD steps with NO cross-cohort
                  collective (client drift is real, as in FedAvg);
                * FedAvg = mean over G (one weight all-reduce per round — the
                  L-fold collective reduction vs. per-step DP);
                * split-FL path: activation maps at split layer j, PCA +
                  K-means selection per cohort, all-gather of the <1%
                  representative maps, server-side upper training from
                  W_G^u(0), compose (the paper's entire §3 in the graph).
prefill_step — causal forward, last-position logits, KV cache unfilled
               (prefill FLOPs/bytes dominate; cache write adds HBM traffic).
decode_step  — one token against the (ring-buffer) cache.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core import selection as sel
from repro.models import layers as L
from repro.models.transformer import LM, decompose, layer_specs, stage_layers
from repro.optim import sgd

PyTree = Any


def _dtype(tcfg: TrainConfig):
    return jnp.bfloat16 if tcfg.dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------------------
# train: one federated round per step
# --------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, lm: Optional[LM] = None):
    lm = lm or LM(cfg)
    opt = sgd(tcfg.lr, momentum=tcfg.momentum,
              weight_decay=tcfg.weight_decay)
    dt = _dtype(tcfg)

    # split boundary (stage-aligned) for the split-FL metadata path
    j = cfg.split_layer
    stages_with_split = decompose(layer_specs(cfg), boundary=j)
    act_spec = None
    if tcfg.seq_shard_activations:
        from jax.sharding import PartitionSpec as P
        act_spec = P(None, "model", None)
    lm_split = LM(cfg, remat=tcfg.remat, act_spec=act_spec)
    lm_split.stages = stages_with_split
    boundary_stage, acc = 0, 0
    for si, st in enumerate(stages_with_split):
        if acc >= j:
            boundary_stage = si
            break
        acc += stage_layers(st)

    def local_loss(p, batch):
        return lm_split.loss(p, batch, dtype=dt)

    def one_cohort(params, opt_state, tokens, extras):
        """L local steps (each over microbatches w/ grad accumulation)."""
        def local_step(carry, step_batch):
            p, s = carry
            tok_mb, ex_mb = step_batch     # (n_micro, mb, T)

            def micro(g_acc, mb):
                t, e = mb
                batch = dict(tokens=t, **e)
                loss, g = jax.value_and_grad(local_loss)(p, batch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return g_acc, loss

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                              p)
            g_sum, losses = jax.lax.scan(micro, g0, (tok_mb, ex_mb))
            n_micro = tok_mb.shape[0]
            g_mean = jax.tree.map(lambda g: g / n_micro, g_sum)
            p, s = opt.apply(g_mean, s, p)
            return (p, s), losses.mean()

        (params, opt_state), losses = jax.lax.scan(
            local_step, (params, opt_state), (tokens, extras))
        return params, opt_state, losses.mean()

    def train_step(client_params, opt_state, batch, key):
        """client_params: G-stacked full-model pytree.
        batch: {"tokens": (G, L, n_micro, mb, T), optional extras}."""
        tokens = batch["tokens"]
        g_ax = tokens.shape[0]
        extras = {k: batch[k] for k in ("prefix_embeds", "enc_frames")
                  if k in batch}

        # extras leaves are (G, L, n_micro, mb, ...); {} vmaps trivially
        new_p, new_s, loss = jax.vmap(one_cohort)(
            client_params, opt_state, tokens, extras)

        # ---- FedAvg (Eq. 2): ONE collective for the whole round ----
        if tcfg.fedavg_compress == "bf16":
            # communicate cohort DELTAS in bf16 (cohorts start each round
            # from identical weights, so deltas are small): halves the
            # round's weight all-reduce bytes; mean is accumulated in f32
            base = jax.tree.map(lambda x: x[0], client_params)
            avg = jax.tree.map(
                lambda b, n: b + (jnp.sum((n - b[None]).astype(jnp.bfloat16),
                                          0) / n.shape[0]).astype(b.dtype),
                base, new_p)
        else:
            avg = jax.tree.map(lambda x: jnp.mean(x, 0), new_p)

        metrics = {"loss": loss.mean()}

        if tcfg.split_fl:
            # ---- the paper's §3.1-3.3 on-mesh ----
            probe = tokens[:, 0, 0]                       # (G, mb, T)
            probe_ex = {k: v[:, 0, 0] for k, v in extras.items()}

            def lower_acts(p_full, toks, ex):
                h, _, _ = lm_split.apply(
                    p_full, toks, mode="full",
                    stage_range=(0, boundary_stage), dtype=dt, **ex)
                return h                                   # (mb, T(+P), d)

            acts = jax.vmap(lower_acts)(new_p, probe, probe_ex)  # (G,mb,T,d)
            pooled = acts.mean(2)                          # (G, mb, d)

            def select_one(feats, k_):
                s_ = sel.select_metadata(
                    feats, None, k_, per_class=False,
                    clusters_per_class=tcfg.meta_clusters,
                    pca_components=min(tcfg.pca_components,
                                       feats.shape[0] - 1),
                    kmeans_iters=8)
                return s_.indices, s_.valid

            keys = jax.random.split(key, g_ax)
            idx, valid = jax.vmap(select_one)(pooled, keys)   # (G, K)
            take0 = lambda a, i: jnp.take(a, i, 0)
            sel_acts = jax.vmap(take0)(acts, idx)
            sel_tok = jax.vmap(take0)(probe, idx)
            sel_ex = {k: jax.vmap(take0)(v, idx) for k, v in probe_ex.items()}
            # server aggregation == all-gather of the selected maps
            k_sel = sel_acts.shape[1]
            meta_acts = sel_acts.reshape(g_ax * k_sel, *sel_acts.shape[2:])
            meta_tok = sel_tok.reshape(g_ax * k_sel, -1)
            meta_ex = {k: v.reshape((g_ax * k_sel,) + v.shape[2:])
                       for k, v in sel_ex.items()}
            meta_w = valid.reshape(-1).astype(jnp.float32)

            # meta-train upper part from W_G^u(0) == init-scaled avg here:
            # faithful variant keeps a dedicated upper0 — passed via params0
            upper_stages = [avg["stages"][i]
                            for i in range(boundary_stage,
                                           len(stages_with_split))]
            upper = {"stages": upper_stages,
                     "final_norm": avg["final_norm"]}
            if "lm_head" in avg:
                upper["lm_head"] = avg["lm_head"]

            n_prefix = (cfg.num_prefix_tokens
                        if "prefix_embeds" in extras else 0)

            def upper_loss(up, a_mb, t_mb, w_mb, ex_mb):
                p_view = {"stages": [None] * boundary_stage
                          + list(up["stages"]),
                          "final_norm": up["final_norm"],
                          "embed": avg["embed"]}
                if "lm_head" in up:
                    p_view["lm_head"] = up["lm_head"]
                if cfg.is_encoder_decoder:   # cross-attn in the upper half
                    p_view["enc_stages"] = avg["enc_stages"]
                    p_view["enc_norm"] = avg["enc_norm"]
                h, _, aux = lm_split.apply(
                    p_view, None, mode="full", hidden_in=a_mb,
                    stage_range=(boundary_stage, len(stages_with_split)),
                    return_hidden=True, dtype=dt,
                    enc_frames=ex_mb.get("enc_frames"))
                h = h[:, n_prefix:]
                hn = L.rms_norm(h, up["final_norm"].astype(h.dtype),
                                cfg.norm_eps)
                if "lm_head" in up:
                    logits = hn @ up["lm_head"].astype(h.dtype)
                else:
                    logits = hn @ avg["embed"].T.astype(h.dtype)
                lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
                nll = -jnp.take_along_axis(lp, t_mb[:, 1:][..., None],
                                           -1)[..., 0]
                per = nll.mean(-1) + aux
                return (per * w_mb).sum() / jnp.maximum(w_mb.sum(), 1.0)

            def meta_step(up, _):
                loss_m, gm = jax.value_and_grad(upper_loss)(
                    up, meta_acts, meta_tok, meta_w, meta_ex)
                up = jax.tree.map(lambda p_, g_: p_ - tcfg.lr * g_, up, gm)
                return up, loss_m

            upper, meta_losses = jax.lax.scan(
                meta_step, upper, None, length=tcfg.meta_steps)
            metrics["meta_loss"] = meta_losses.mean()
            metrics["selected"] = meta_w.sum()
            # composed model = [avg lower ; meta-trained upper]
            avg = dict(avg, **{"final_norm": upper["final_norm"]})
            avg["stages"] = (list(avg["stages"][:boundary_stage])
                             + list(upper["stages"]))
            if "lm_head" in upper:
                avg["lm_head"] = upper["lm_head"]

        # redistribute: next round every cohort starts from W_G(t)
        new_client_params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (g_ax,) + x.shape), avg)
        return new_client_params, new_s, metrics

    return train_step, lm_split


# --------------------------------------------------------------------------
# inference steps
# --------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, force_swa: bool = False,
                      dtype=jnp.bfloat16):
    lm = LM(cfg, force_swa=force_swa)

    def prefill_step(params, batch):
        extras = {k: batch[k] for k in ("prefix_embeds", "enc_frames")
                  if k in batch}
        h_all, _, _ = lm.apply(params, batch["tokens"], mode="full",
                               return_hidden=True, dtype=dtype, **extras)
        # last-position logits only (vocab projection on one position)
        h = L.rms_norm(h_all[:, -1:], params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            out = h @ params["embed"].T.astype(h.dtype)
        else:
            out = h @ params["lm_head"].astype(h.dtype)
        return out

    return prefill_step, lm


def make_decode_step(cfg: ModelConfig, force_swa: bool = False,
                     dtype=jnp.bfloat16):
    lm = LM(cfg, force_swa=force_swa)

    def decode_step(params, cache, tokens):
        logits, new_cache, _ = lm.apply(params, tokens, mode="decode",
                                        cache=cache, dtype=dtype)
        next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        return next_tok, new_cache

    return decode_step, lm
