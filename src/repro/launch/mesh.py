"""Production mesh builders. Defined as FUNCTIONS so importing this module
never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init).

Production target: TPU v5e, 256 chips/pod (16x16), optionally 2 pods.
  axes: data (batch / federated cohorts / FSDP), model (tensor/expert), pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CI on a handful of host devices (2x2 or 2x2x2...)."""
    n = len(jax.devices())
    if multi_pod and n >= 8:
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    if n >= 4:
        return jax.make_mesh((2, 2), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) — used by the roofline report.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link (~per-chip effective)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB
VMEM_BYTES = 128 * 1024 ** 2
