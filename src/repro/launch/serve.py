"""Batched decode serving launcher: prefill a batch of prompts, then decode
with the (ring-buffer) KV cache under jit. --smoke runs a reduced config on
the smoke mesh with real execution (this container); without --smoke it
expects the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke --tokens 16
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.obs.timing import monotonic


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh, make_smoke_mesh
    from repro.launch.steps import make_decode_step
    from repro.models.transformer import LM

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_smoke_mesh()
    else:
        mesh = make_production_mesh()

    decode_fn, lm = make_decode_step(cfg)
    with mesh:
        params = lm.init(jax.random.PRNGKey(0))
        cache = lm.init_cache(args.batch, args.cache_len)
        if cfg.is_encoder_decoder:
            cache["enc_out"] = jnp.zeros(
                (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
        jit_decode = jax.jit(decode_fn)

        rng = np.random.default_rng(0)
        # "prefill" by teacher-forcing the prompt through decode steps (keeps
        # one compiled program; a production server uses the prefill step)
        prompt = rng.integers(0, cfg.vocab_size,
                              (args.batch, args.prompt_len), np.int32)
        t0 = monotonic()
        tok = jnp.asarray(prompt[:, :1])
        for i in range(1, args.prompt_len):
            _, cache = jit_decode(params, cache, tok)
            tok = jnp.asarray(prompt[:, i:i + 1])
        t_prefill = monotonic() - t0

        out = []
        t0 = monotonic()
        for _ in range(args.tokens):
            tok, cache = jit_decode(params, cache, tok)
            out.append(np.asarray(tok)[:, 0])
        dt = monotonic() - t0
        out = np.stack(out, 1)
    print(f"prompt fed in {t_prefill:.2f}s; generated {args.tokens} tokens x "
          f"batch {args.batch} in {dt:.2f}s "
          f"({args.tokens*args.batch/max(dt,1e-9):.1f} tok/s)")
    print("sample token ids:", out[0][:16].tolist())
    print("serve: done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
