"""Sharding planner: maps every param/batch/cache leaf to a PartitionSpec for
the production mesh, by leaf name + divisibility checks (DESIGN.md §6).

Strategies:
  * tp    — tensor parallel on "model" (column/row-parallel per leaf kind);
            experts on "model" for MoE. Used by every mode.
  * fsdp  — additionally shard a second dim over "data" for the huge archs
            (jamba 398B, deepseek 236B) so weights fit HBM; GSPMD inserts the
            FSDP all-gathers automatically.
  * fed   — stacked-clients axis (leading G) over "data" (and/or "pod") for
            the paper's FedAvg train step.

Anything non-divisible falls back to replication (recorded in the plan so
EXPERIMENTS.md can report what replicated and why).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any

FSDP_THRESHOLD = 40e9   # params; above this weights also shard over "data"

# leaf-name -> (col_dims, row_dims): which dims prefer model-axis sharding.
# col = output/feature dim, row = reduction dim (row-parallel => psum).
_COL = {"wq", "wk", "wv", "wg", "cwq", "cwk", "cwv", "w_gate", "w_up",
        "ws_gate", "ws_up", "w_uq", "w_uk", "w_uv", "w_in", "w_dt",
        "w_decay2", "wr", "lm_head", "wk_ffn"}
_ROW = {"wo", "cwo", "w_down", "ws_down", "w_out", "w_x", "wv_ffn"}
_EXPERT = {"we_gate", "we_up", "we_down"}
_REPLICATE = {"router", "w_dq", "w_dkv", "w_kr", "q_norm", "kv_norm",
              "conv_w", "conv_b", "bonus", "mu_r", "mu_k", "mu_v", "mu_w",
              "mu_g", "w_decay1", "decay_bias", "dt_bias", "A_log", "D",
              "ln_x", "norm", "cross_norm", "final_norm", "enc_norm", "proj",
              "scale", "bias", "fc_b", "bq", "bk", "bv"}


@dataclass
class Plan:
    mesh: Mesh
    params: PyTree                   # PartitionSpec tree matching params
    replicated: List[str] = field(default_factory=list)
    notes: List[str] = field(default_factory=list)

    def shardings(self) -> PyTree:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.params,
                            is_leaf=lambda x: isinstance(x, P))


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _leaf_name(path) -> str:
    for p in reversed(path):
        k = getattr(p, "key", None)
        if isinstance(k, str):
            return k
    return ""


def _prefix_depth(path) -> int:
    """Number of leading stacking axes: stage params live under
    params['stages'][si][unit_pos] -> scan stages have a repeats axis."""
    # path looks like ('stages', si, unit_pos, 'mixer'/'ffn', leafname)
    return 0


def plan_params(cfg: ModelConfig, mesh: Mesh, params_shapes: PyTree, *,
                fed_axes: Optional[Tuple[str, ...]] = None,
                fsdp: Optional[bool] = None,
                head_aware: bool = True,
                scan_stage_ids: Optional[set] = None) -> Plan:
    """Build PartitionSpecs for a params pytree (of ShapeDtypeStructs).

    fed_axes:   mesh axes carrying the stacked-clients axis (train mode); the
                params tree is then expected to have that extra LEADING axis.
    fsdp:       shard a second weight dim over "data" (default: auto by size).
    head_aware: replicate attention weights when heads don't divide the model
                axis (avoids fractional-head SPMD rematerialization). Right
                for inference and for seq-sharded-activation training; WRONG
                for plain training (replicated attention = model-axis-times
                the attention compute per device) — see §Perf H2/G iterations.
    """
    m = _axis_size(mesh, "model")
    d_axis = _axis_size(mesh, "data")
    if fsdp is None:
        from repro.models.registry import count_params
        fsdp = count_params(cfg) > FSDP_THRESHOLD
    use_data_dim = fsdp and "data" not in (fed_axes or ())
    plan = Plan(mesh, None)
    if fsdp:
        plan.notes.append("fsdp: second weight dim sharded over 'data'")

    def spec_for(path, leaf) -> P:
        shape = tuple(leaf.shape)
        name = _leaf_name(path)
        pathstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
        # rwkv channel-mix reuses wk/wv/wr names with transposed roles
        if "ffn" in pathstr and name in ("wk", "wv", "wr"):
            name = {"wk": "wk_ffn", "wv": "wv_ffn", "wr": "wr_ffn"}[name]
        # how many leading stacking axes does this leaf carry?
        nstack = len(shape) - _base_ndim(cfg, name)
        nstack = max(nstack, 0)
        base = list(_base_spec(cfg, name, shape[nstack:], m,
                               d_axis if use_data_dim else 0, plan,
                               head_aware=head_aware))
        spec = [None] * nstack + base
        if fed_axes:
            # leading axis 0 is the client/cohort axis
            spec[0] = fed_axes if len(fed_axes) > 1 else fed_axes[0]
        return P(*spec)

    flat = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    specs = {}
    leaves = []
    for path, leaf in flat:
        leaves.append(spec_for(path, leaf))
    treedef = jax.tree_util.tree_structure(params_shapes)
    plan.params = jax.tree_util.tree_unflatten(treedef, leaves)
    return plan


def _base_ndim(cfg: ModelConfig, name: str) -> int:
    """ndim of the leaf BEFORE any stacking (scan repeats / client axis)."""
    if name in _EXPERT:
        return 3
    if name in ("embed", "lm_head", "fc_w", "embed_head"):
        return 2
    if name in _COL | _ROW | {"w_decay1", "w_dkv", "w_kr", "w_dq", "proj",
                              "wr_ffn"}:
        return 2
    if name in ("conv_w", "A_log", "bonus"):
        return 2
    if name in ("conv_in", "conv1", "conv2", "shortcut"):
        return 4
    return 1   # norms, biases, mus


_ATTN_HEADED = {"wq", "cwq", "wg", "wr", "bq"}       # q/gate: num_heads-shaped
_ATTN_KV_HEADED = {"wk", "wv", "cwk", "cwv", "bk", "bv"}  # kv-heads-shaped
_ATTN_OUT = {"wo", "cwo"}


def _base_spec(cfg: ModelConfig, name: str, shape, m: int, d_axis: int,
               plan: Plan, head_aware: bool = True):
    """PartitionSpec dims for the unstacked leaf."""
    def div(i, ax):
        return ax > 1 and shape[i] % ax == 0

    # Head-aware rule (EXPERIMENTS.md §Perf H2): sharding the FLAT h*hd dim
    # when heads don't divide the axis puts fractional heads on each device;
    # every (b,s,h,hd) reshape then forces SPMD full rematerialization and
    # GB-scale all-gathers. Replicating attention weights is strictly better
    # for those archs (gemma3 8H, phi3 40H, qwen2 14H, rwkv6 40H vs 16-wide
    # model axis) in inference / seq-sharded training; FFN/vocab still shard.
    if head_aware:
        heads_ok = cfg.num_heads % m == 0
        kv_ok = cfg.num_kv_heads % m == 0
        if ((name in _ATTN_HEADED and not heads_ok)
                or (name in _ATTN_KV_HEADED and not kv_ok)
                or (name in _ATTN_OUT and not heads_ok)):
            plan.replicated.append(name)
            return [None] * len(shape)

    dims = [None] * len(shape)
    if name in ("embed", "embed_head"):
        if div(0, m):
            dims[0] = "model"
        if d_axis and div(1, d_axis):
            dims[1] = "data"
        return dims
    if name in ("lm_head", "fc_w"):
        if div(1, m):
            dims[1] = "model"
        if d_axis and div(0, d_axis):
            dims[0] = "data"
        return dims
    if name in _EXPERT:
        if div(0, m):
            dims[0] = "model"                 # expert parallelism
        if d_axis and div(1, d_axis):
            dims[1] = "data"                  # fsdp on d_model dim
        return dims
    if name in _COL and len(shape) == 2:
        if div(1, m):
            dims[1] = "model"
        else:
            plan.replicated.append(name)
        if d_axis and div(0, d_axis):
            dims[0] = "data"
        return dims
    if name in _ROW and len(shape) == 2:
        if div(0, m):
            dims[0] = "model"
        else:
            plan.replicated.append(name)
        if d_axis and div(1, d_axis):
            dims[1] = "data"
        return dims
    # conv / norms / biases / everything else: replicate
    return dims


# --------------------------------------------------------------------------
# batch & cache specs
# --------------------------------------------------------------------------
def batch_spec(mesh: Mesh, *, fed_axes: Tuple[str, ...] = (),
               batch_axes: Tuple[str, ...] = ("data",)) -> P:
    """Spec builder for (G?, steps?, B, ...) shaped batches is done in
    specs.py; this returns the batch-dim axes tuple usable there."""
    avail = [a for a in batch_axes if _axis_size(mesh, a) > 1]
    return tuple(avail)


def cache_plan(cfg: ModelConfig, mesh: Mesh, cache_shapes: PyTree,
               batch: int, seq_shard: bool = False) -> PyTree:
    """KV/SSM cache PartitionSpecs. Batch dim over 'data' (and 'pod') when it
    divides; batch==1 (long_500k) -> shard the SEQUENCE dim over 'data'
    instead (sequence-parallel cache; DESIGN.md §6). kv-head/latent dims over
    'model' when divisible.

    seq_shard=True (the §Perf H2 optimization): shard the cache SEQUENCE dim
    over 'model' instead of splitting kv-heads/head-dim. Decode attention
    then reduces over the sharded seq dim (psum of softmax stats + a tiny
    per-layer output psum) instead of resharding fractional heads."""
    m = _axis_size(mesh, "model")
    d_axis = _axis_size(mesh, "data")
    p_axis = _axis_size(mesh, "pod")
    bdims: Tuple[str, ...] = ()
    if p_axis > 1 and batch % (d_axis * p_axis) == 0:
        bdims = ("pod", "data")
    elif batch % d_axis == 0 and d_axis > 1:
        bdims = ("data",)

    def _bspec(s, off):
        if bdims:
            s[off] = bdims if len(bdims) > 1 else bdims[0]

    def spec(path, leaf):
        shape = tuple(leaf.shape)
        name = _leaf_name(path)
        if name == "pos":
            return P()
        if name in ("k", "v"):                 # (stack?, B, S, KV, HD)
            off = len(shape) - 4
            s = [None] * len(shape)
            _bspec(s, off)
            if seq_shard and shape[off + 1] % m == 0:
                s[off + 1] = "model"
                if not bdims and shape[off + 1] % (m * d_axis) == 0:
                    s[off + 1] = ("data", "model")
            else:
                if not bdims and shape[off + 1] % d_axis == 0:
                    s[off + 1] = "data"
                if shape[off + 2] % m == 0:
                    s[off + 2] = "model"
                elif shape[off + 3] % m == 0:
                    s[off + 3] = "model"
            return P(*s)
        if name in ("c_kv", "k_rope"):         # (stack?, B, S, R)
            off = len(shape) - 3
            s = [None] * len(shape)
            _bspec(s, off)
            if seq_shard and shape[off + 1] % m == 0:
                s[off + 1] = "model"
                if not bdims and shape[off + 1] % (m * d_axis) == 0:
                    s[off + 1] = ("data", "model")
            else:
                if not bdims and shape[off + 1] % d_axis == 0:
                    s[off + 1] = "data"
                if name == "c_kv" and shape[off + 2] % m == 0:
                    s[off + 2] = "model"
            return P(*s)
        if name == "ssm":                      # (stack?, B, DI, ST)
            off = len(shape) - 3
            s = [None] * len(shape)
            if bdims:
                s[off] = bdims if len(bdims) > 1 else bdims[0]
            if shape[off + 1] % m == 0:
                s[off + 1] = "model"
            return P(*s)
        if name == "conv":                     # (stack?, B, CW-1, DI)
            off = len(shape) - 3
            s = [None] * len(shape)
            if bdims:
                s[off] = bdims if len(bdims) > 1 else bdims[0]
            if shape[off + 2] % m == 0:
                s[off + 2] = "model"
            return P(*s)
        if name == "state":                    # rwkv (stack?, B, H, HD, HD)
            off = len(shape) - 4
            s = [None] * len(shape)
            if bdims:
                s[off] = bdims if len(bdims) > 1 else bdims[0]
            if shape[off + 1] % m == 0:
                s[off + 1] = "model"
            return P(*s)
        if name in ("x_prev", "ffn_x_prev"):   # (stack?, B, D)
            off = len(shape) - 2
            s = [None] * len(shape)
            if bdims:
                s[off] = bdims if len(bdims) > 1 else bdims[0]
            if shape[off + 1] % m == 0:
                s[off + 1] = "model"
            return P(*s)
        if name == "enc_out":                  # (B, ENC, D)
            s = [None, None, None]
            if bdims:
                s[0] = bdims if len(bdims) > 1 else bdims[0]
            return P(*s)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)
