"""Distributed federated-split training launcher.

On the production mesh this runs the paper's Algorithm 1 at pod scale: one
jit-compiled federated round per step (L local steps -> FedAvg all-reduce ->
metadata selection -> server-side upper training). On this CPU container use
--smoke (reduced config, smoke mesh, synthetic data, real execution).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke --steps 4
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.obs.timing import monotonic


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--no-split-fl", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs import INPUT_SHAPES, TrainConfig, get_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_production_mesh, make_smoke_mesh
    from repro.launch.specs import fed_layout, input_specs
    from repro.launch.steps import make_train_step
    from repro.checkpoint import CheckpointManager

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_smoke_mesh()
    else:
        mesh = make_production_mesh()
    tcfg = TrainConfig(local_steps=args.local_steps,
                       split_fl=not args.no_split_fl,
                       microbatch=min(8, args.global_batch))
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")

    step_fn, lm = make_train_step(cfg, tcfg)
    specs = input_specs(cfg, shape, mesh, tcfg, lm=lm)
    g = specs["g"]

    key = jax.random.PRNGKey(0)
    with mesh:
        jit_step = jax.jit(step_fn)
        params0 = lm.init(jax.random.PRNGKey(1))
        client_params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (g,) + x.shape), params0)
        client_params = jax.device_put(
            client_params, jax.tree.map(lambda s: s.sharding,
                                        specs["params"]))
        opt_state = ()
        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

        tok_spec = specs["batch"]["tokens"]
        rng = np.random.default_rng(0)
        for t in range(args.steps):
            batch = {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, tok_spec.shape, np.int32))}
            for k2, v in specs["batch"].items():
                if k2 != "tokens":
                    batch[k2] = jnp.asarray(
                        rng.normal(0, 1, v.shape).astype(np.float32))
            key, sub = jax.random.split(key)
            t0 = monotonic()
            client_params, opt_state, metrics = jit_step(
                client_params, opt_state, batch, sub)
            metrics = jax.tree.map(float, metrics)
            print(f"round {t}: {metrics}  ({monotonic()-t0:.2f}s)")
            if mgr:
                avg = jax.tree.map(lambda x: np.asarray(x[0]), client_params)
                mgr.save(t, avg, {"arch": args.arch})
    print("train: done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
