# NOTE: deliberately empty of jax imports — dryrun.py must set XLA_FLAGS
# before anything touches jax.
