"""Post-SPMD HLO cost model with while-loop trip-count expansion.

XLA's compiled.cost_analysis() on the CPU backend does NOT multiply while-
loop bodies by their trip counts, so a lax.scan over 34 layers counts one
layer of FLOPs. This module re-derives, from compiled.as_text():

  * flops        — 2*prod(out)*contract for every dot (matmuls dominate all
                   models here), expanded through while/call/fusion edges;
  * bytes        — per-op operand+output bytes (fusion internals excluded:
                   a fusion op touches HBM only at its boundary), expanded;
  * collectives  — all-gather / all-reduce / reduce-scatter / all-to-all /
                   collective-permute operand bytes by kind, expanded.

The compiled module is the PER-PARTITION program, so all numbers are
per-device — exactly what the roofline terms want.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that move no HBM bytes of their own
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# type part may contain tuple element comments like /*index=5*/; the op name
# is the first space-preceded word(...) after the '=' (layout tiling ':T(..)'
# is colon-preceded, so it can't false-match).
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    edges: List[Tuple[str, float, bool]] = field(default_factory=list)
    # (callee, mult, is_fusion): fusion children contribute flops only


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)
    unknown_trips: int = 0

    @property
    def collective_total(self) -> float:
        return sum(self.coll_bytes.values())

    def as_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "transcendentals": self.transcendentals,
                "collective_bytes": self.collective_total,
                "coll_bytes_by_kind": dict(self.coll_bytes),
                "coll_count_by_kind": dict(self.coll_count),
                "unknown_trip_counts": self.unknown_trips}


def _operand_shapes(args: str, symtab: Dict[str, str]) -> List[List[int]]:
    """Per-operand dims for an instruction's argument list.  Modern HLO
    annotates operands inline ('f32[64,32]{1,0} %Arg_0.1'); older dumps
    give bare names ('%Arg_0.1') resolved via the symbol table."""
    seg = args.split(")", 1)[0]
    inline = _TYPE_RE.findall(seg)
    if inline:
        return [[int(x) for x in dims.split(",")] if dims else []
                for _, dims in inline]
    shapes: List[List[int]] = []
    for name in re.findall(r"%([\w\.\-]+)", seg):
        sh = _first_shape(symtab.get(name, ""))
        shapes.append(sh[1] if sh else [])
    return shapes


def _dot_flops(out_type: str, args: str, symtab: Dict[str, str],
               line: str) -> float:
    out = _first_shape(out_type)
    if out is None:
        return 0.0
    _, out_dims = out
    out_n = 1
    for d in out_dims:
        out_n *= d
    # contraction size from lhs operand dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    shapes = _operand_shapes(args, symtab)
    contract = 1
    if m and shapes and shapes[0]:
        dims = shapes[0]
        for i in m.group(1).split(","):
            if i != "" and int(i) < len(dims):
                contract *= dims[int(i)]
    return 2.0 * out_n * max(contract, 1)


def _conv_flops(out_type: str, line: str, symtab, args) -> float:
    out = _first_shape(out_type)
    if out is None:
        return 0.0
    _, out_dims = out
    out_n = 1
    for d in out_dims:
        out_n *= d
    m = re.search(r"window=\{size=([\dx]+)", line)
    spatial = 1
    if m:
        for s in m.group(1).split("x"):
            spatial *= int(s)
    shapes = _operand_shapes(args, symtab)
    cin = 1
    if len(shapes) > 1 and len(shapes[1]) >= 3:
        cin = shapes[1][-2]   # HWIO kernel: I dim
    return 2.0 * out_n * spatial * cin


def parse_hlo(text: str) -> HloCost:
    comps: Dict[str, _Comp] = {}
    entry = None
    cur: Optional[_Comp] = None
    symtab: Dict[str, str] = {}
    unknown_trips = 0

    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr and (line.endswith("{") or "{" in line.split("->")[-1]):
            cur = _Comp(hdr.group(2))
            comps[cur.name] = cur
            symtab = {}
            if hdr.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, out_type, op, rest = m.groups()
        symtab[name] = out_type

        if op in _FREE_OPS:
            continue

        out_b = _type_bytes(out_type)
        arg_names = re.findall(r"%([\w\.\-]+)", rest.split(")", 1)[0])
        in_b = sum(_type_bytes(symtab.get(a, "")) for a in arg_names)

        if op == "fusion":
            cur.bytes += out_b + in_b
            mcal = re.search(r"calls=%?([\w\.\-]+)", line)
            if mcal:
                cur.edges.append((mcal.group(1), 1.0, True))
            continue
        if op == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            mt = re.search(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"', line)
            trip = int(mt.group(1)) if mt else None
            if trip is None:
                trip = 1
                unknown_trips += 1
            if mb:
                cur.edges.append((mb.group(1), float(trip), False))
            if mc:
                cur.edges.append((mc.group(1), float(trip), False))
            continue
        if op in ("call", "custom-call", "conditional", "async-start"):
            for mcal in re.finditer(
                    r"(?:to_apply|called_computations=\{?)%?([\w\.\-]+)", line):
                cur.edges.append((mcal.group(1), 1.0, False))
            cur.bytes += out_b + in_b
            continue

        is_coll = False
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                cur.coll_bytes[c] += out_b
                cur.coll_count[c] += 1
                cur.bytes += out_b + in_b
                is_coll = True
                break
        if is_coll:
            continue

        if op == "dot":
            cur.flops += _dot_flops(out_type, rest, symtab, line)
            cur.bytes += out_b + in_b
            continue
        if op == "convolution":
            cur.flops += _conv_flops(out_type, line, symtab, rest)
            cur.bytes += out_b + in_b
            continue
        if op in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                  "logistic", "sine", "cosine"):
            n = _type_bytes(out_type) // 4 or 1
            cur.transcendentals += n
        # everything else: elementwise / reduce / dynamic-slice etc.
        cur.bytes += out_b + in_b

    if entry is None and comps:
        entry = next(iter(comps))

    # accumulate with memoized recursion
    memo: Dict[str, Tuple[float, float, float, dict, dict]] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 64:
            return (0.0, 0.0, 0.0, {}, {})
        fl, by, tr = c.flops, c.bytes, c.transcendentals
        cb = dict(c.coll_bytes)
        cc = dict(c.coll_count)
        for callee, mult, is_fusion in c.edges:
            if callee == name:
                continue
            f2, b2, t2, cb2, cc2 = total(callee, depth + 1)
            fl += f2 * mult
            tr += t2 * mult
            if not is_fusion:
                by += b2 * mult
                for k, v in cb2.items():
                    cb[k] = cb.get(k, 0.0) + v * mult
                for k, v in cc2.items():
                    cc[k] = cc.get(k, 0) + int(v * mult)
        memo[name] = (fl, by, tr, cb, cc)
        return memo[name]

    fl, by, tr, cb, cc = total(entry) if entry else (0, 0, 0, {}, {})
    return HloCost(flops=fl, bytes=by, transcendentals=tr, coll_bytes=cb,
                   coll_count=cc, unknown_trips=unknown_trips)


# backwards-compatible helpers -------------------------------------------
@dataclass
class CollectiveStats:
    cost: HloCost

    @property
    def total_bytes(self):
        return self.cost.collective_total

    def as_dict(self):
        return {"total_bytes": self.cost.collective_total,
                "bytes_by_kind": dict(self.cost.coll_bytes),
                "count_by_kind": dict(self.cost.coll_count),
                "unknown_trip_counts": self.cost.unknown_trips}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    return CollectiveStats(parse_hlo(hlo_text))
