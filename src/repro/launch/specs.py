"""input_specs(): ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
ZERO device allocation) for every (arch x input-shape x mesh) combination —
params, optimizer state, batch, KV caches.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.launch import sharding as sh
from repro.models.transformer import LM

PyTree = Any


def _axis(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _with_shardings(shapes: PyTree, plan_specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        shapes, plan_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def fed_layout(cfg: ModelConfig, mesh: Mesh) -> Tuple[int, Tuple[str, ...]]:
    """(G cohorts, fed mesh axes) for the train step — DESIGN.md §6."""
    from repro.models.registry import count_params
    huge = count_params(cfg) > sh.FSDP_THRESHOLD
    p_ax, d_ax = _axis(mesh, "pod"), _axis(mesh, "data")
    if huge:
        return (p_ax, ("pod",)) if p_ax > 1 else (1, ())
    if p_ax > 1:
        return p_ax * d_ax, ("pod", "data")
    return d_ax, ("data",)


def _stack_shapes(shapes: PyTree, g: int) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((g,) + tuple(s.shape), s.dtype),
        shapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def param_specs(cfg: ModelConfig, mesh: Mesh, lm: Optional[LM] = None,
                fed_axes: Optional[Tuple[str, ...]] = None,
                g: int = 0, param_dtype=jnp.float32,
                head_aware: bool = True) -> PyTree:
    lm = lm or LM(cfg)
    shapes = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, param_dtype), shapes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    if fed_axes is not None and g > 0:
        shapes = _stack_shapes(shapes, g)
    plan = sh.plan_params(cfg, mesh, shapes,
                          fed_axes=fed_axes if g > 0 else None,
                          head_aware=head_aware)
    return _with_shardings(shapes, plan.params, mesh), plan


def _extras_specs(cfg: ModelConfig, lead: tuple, mesh: Mesh, lead_spec: tuple,
                  dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Frontend stub inputs (the one sanctioned stub — DESIGN.md §5)."""
    ex = {}
    if cfg.frontend == "vision_stub":
        shape = lead + (cfg.num_prefix_tokens, cfg.d_model)
        ex["prefix_embeds"] = _sds(shape, dtype, mesh,
                                   P(*lead_spec, None, None))
    if cfg.frontend == "audio_stub":
        shape = lead + (cfg.encoder_seq_len, cfg.d_model)
        ex["enc_frames"] = _sds(shape, dtype, mesh,
                                P(*lead_spec, None, None))
    return ex


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                tcfg: Optional[TrainConfig] = None,
                force_swa: bool = False, lm: Optional[LM] = None,
                cache_seq_shard: bool = False) -> Dict[str, Any]:
    """Everything .lower() needs for (arch, shape, mesh): a dict of kwargs for
    the corresponding step function, all ShapeDtypeStructs with shardings.
    ``lm`` MUST be the step's own LM when the stage decomposition differs from
    the default (the train step splits stages at the paper's layer j)."""
    tcfg = tcfg or TrainConfig()
    p_ax, d_ax = _axis(mesh, "pod"), _axis(mesh, "data")

    if shape.kind == "train":
        g, fed_axes = fed_layout(cfg, mesh)
        lm = lm or LM(cfg, remat=tcfg.remat)
        # head-aware attention replication is only right for training when
        # activations are seq-sharded (else attention replicates compute)
        params, plan = param_specs(cfg, mesh, lm, fed_axes=fed_axes, g=g,
                                   head_aware=tcfg.seq_shard_activations)
        cohort_batch = max(shape.global_batch // max(g, 1), 1)
        mb = min(tcfg.microbatch, cohort_batch)
        n_micro = max(cohort_batch // mb, 1)
        lead = (g, tcfg.local_steps, n_micro, mb)
        # batch dims: cohort axis over fed axes; within-cohort rows over any
        # batch axis NOT used by the cohorts (FSDP case: rows over data)
        row_axes = tuple(a for a in ("data",)
                         if a not in fed_axes and _axis(mesh, a) > 1
                         and mb % _axis(mesh, a) == 0)
        fed_spec = (fed_axes if len(fed_axes) > 1 else
                    (fed_axes[0] if fed_axes else None),)
        lead_spec = fed_spec + (None, None,
                                row_axes if len(row_axes) > 1 else
                                (row_axes[0] if row_axes else None))
        tokens = _sds(lead + (shape.seq_len,), jnp.int32, mesh,
                      P(*lead_spec, None))
        batch = {"tokens": tokens}
        batch.update(_extras_specs(cfg, lead, mesh, lead_spec))
        opt_state = ()                       # plain SGD
        key = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                   sharding=NamedSharding(mesh, P(None)))
        return dict(mode="train", params=params, opt_state=opt_state,
                    batch=batch, key=key, plan=plan, g=g, fed_axes=fed_axes)

    # ---------------- inference ----------------
    lm = lm or LM(cfg, force_swa=force_swa)
    # head-aware attention replication is right for DECODE (attention work is
    # tiny, fractional-head resharding dominates) but wrong for PREFILL
    # (replicated quadratic attention = model-axis-times the work/device) —
    # measured in EXPERIMENTS.md §Perf (gemma prefill 4.97s -> 34.4s when
    # misapplied).
    params, plan = param_specs(cfg, mesh, lm, param_dtype=jnp.bfloat16,
                               head_aware=(shape.kind == "decode"))
    b = shape.global_batch
    if p_ax > 1 and b % (p_ax * d_ax) == 0:
        bspec: Any = ("pod", "data")
    elif b % d_ax == 0 and d_ax > 1:
        bspec = "data"
    else:
        bspec = None

    if shape.kind == "prefill":
        tokens = _sds((b, shape.seq_len), jnp.int32, mesh, P(bspec, None))
        batch = {"tokens": tokens}
        batch.update(_extras_specs(cfg, (b,), mesh, (bspec,)))
        return dict(mode="prefill", params=params, batch=batch, plan=plan)

    # decode: ONE new token against a seq_len cache
    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(b, shape.seq_len, dtype=jnp.bfloat16))
    cplan = sh.cache_plan(cfg, mesh, cache_shapes, b,
                          seq_shard=cache_seq_shard)
    cache = _with_shardings(cache_shapes, cplan, mesh)
    tokens = _sds((b, 1), jnp.int32, mesh, P(bspec, None))
    return dict(mode="decode", params=params, cache=cache, tokens=tokens,
                plan=plan)
