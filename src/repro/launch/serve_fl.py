"""Async FL service launcher: run the event-driven server loop over a
seeded traffic model, optionally under chaos, printing per-run throughput
(ticks/sec, bytes/sec) and the final composed-model accuracy.

  PYTHONPATH=src python -m repro.launch.serve_fl --ticks 6 --traffic poisson \
      --rate 2 --buffer-size 2 --delay-ticks 2
  PYTHONPATH=src python -m repro.launch.serve_fl --sync-check   # oracle mode

``--sync-check`` runs the degenerate configuration (DegenerateTraffic,
buffer == cohort) AND the synchronous ``FLSimulation`` on the same seed,
then asserts the bit-identity contract (weights + ledger) — the CI service
smoke job drives exactly this.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.obs.timing import monotonic


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=4)
    ap.add_argument("--traffic", default="degenerate",
                    choices=["degenerate", "poisson", "diurnal"])
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--delay-ticks", type=int, default=0)
    ap.add_argument("--period", type=int, default=24)
    ap.add_argument("--buffer-size", type=int, default=0,
                    help="0 = cohort size (the sync-degenerate buffer)")
    ap.add_argument("--alpha", type=float, default=0.5,
                    help="FedBuff staleness exponent")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--traffic-seed", type=int, default=0)
    ap.add_argument("--drop", type=float, default=0.0,
                    help="client crash rate (chaos wire when > 0)")
    ap.add_argument("--corrupt", type=float, default=0.0,
                    help="frame bit-flip rate (chaos wire when > 0)")
    ap.add_argument("--trace", default="",
                    help="write the span trace JSONL here")
    ap.add_argument("--sync-check", action="store_true",
                    help="degenerate run + FLSimulation; assert bit-identity")
    args = ap.parse_args(argv)

    import jax  # noqa: F401  (force the backend up before timing)
    from repro.configs import FLConfig, get_wrn_config
    from repro.data import SyntheticImageDataset, partition_k_shards
    from repro.fl.faults import FaultPlan
    from repro.fl.service import (DegenerateTraffic, DiurnalTraffic,
                                  FLService, PoissonTraffic)
    from repro.models.wrn import make_split_wrn

    wrn = get_wrn_config().reduced()
    model = make_split_wrn(wrn)
    train = SyntheticImageDataset(100 * args.clients,
                                  image_size=wrn.image_size, seed=0)
    test = SyntheticImageDataset(100, image_size=wrn.image_size, seed=1)
    clients = partition_k_shards(train, args.clients, k_classes=2,
                                 samples_per_client=40)
    cfg = FLConfig(num_clients=args.clients, clients_per_round=args.clients,
                   local_batch_size=20, pca_components=8,
                   clusters_per_class=3, kmeans_iters=4, meta_epochs=1,
                   meta_batch_size=10,
                   transport_checksum=bool(args.drop or args.corrupt),
                   observability=bool(args.trace))
    plan = None
    if args.drop or args.corrupt:
        plan = FaultPlan(drop_rate=args.drop, bitflip_rate=args.corrupt)

    if args.traffic == "poisson":
        traffic = PoissonTraffic(rate=args.rate, seed=args.traffic_seed,
                                 delay_ticks=args.delay_ticks)
    elif args.traffic == "diurnal":
        traffic = DiurnalTraffic(rate=args.rate, seed=args.traffic_seed,
                                 delay_ticks=args.delay_ticks,
                                 period=args.period)
    else:
        traffic = DegenerateTraffic()

    svc = FLService(model, clients, test, cfg, seed=args.seed,
                    traffic=traffic,
                    buffer_size=args.buffer_size or None,
                    staleness_alpha=args.alpha, fault_plan=plan)
    t0 = monotonic()
    res = svc.run(ticks=args.ticks, drain=(args.traffic != "degenerate"))
    dt = monotonic() - t0
    total_bytes = res.comm.get("total_up", 0) + res.comm.get("total_down", 0)
    acc = res.test_acc[-1] if res.test_acc else float("nan")
    print(f"serve_fl: {args.ticks} ticks, {sum(res.arrivals_per_tick)} "
          f"arrivals, {res.flushes} flushes in {dt:.2f}s "
          f"({args.ticks / max(dt, 1e-9):.2f} ticks/s, "
          f"{total_bytes / max(dt, 1e-9):.0f} B/s)")
    print(f"serve_fl: M_COM acc={acc:.4f}  "
          f"mean staleness={res.mean_staleness:.2f}  "
          f"drops={sum(res.drops)}")
    if args.trace and svc.tracer.enabled:
        svc.tracer.write_jsonl(args.trace)
        print(f"serve_fl: trace -> {args.trace}")

    if args.sync_check and (args.traffic != "degenerate"
                            or args.buffer_size):
        ap.error("--sync-check requires degenerate traffic and the "
                 "default (cohort-sized) buffer")
    if args.sync_check:
        from repro.fl.simulation import FLSimulation
        sim = FLSimulation(model, clients, test, cfg, seed=args.seed,
                           fault_plan=plan)
        sres = sim.run(rounds=args.ticks, eval_every=args.ticks)
        sl = jax.tree_util.tree_leaves(sim.server.global_params)
        vl = jax.tree_util.tree_leaves(svc.server.global_params)
        same_w = all(np.array_equal(np.asarray(a), np.asarray(b))
                     for a, b in zip(sl, vl))
        sim_comm = {k: v for k, v in sres.comm.items()
                    if k != "total_samples"}
        same_l = dict(res.comm) == sim_comm
        print(f"serve_fl: sync-check weights={'OK' if same_w else 'FAIL'} "
              f"ledger={'OK' if same_l else 'FAIL'}")
        if not (same_w and same_l):
            return 1
    print("serve_fl: done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
