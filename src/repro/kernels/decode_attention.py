"""Pallas TPU kernel: flash-decode — ONE query token against a long
(ring-buffer) KV cache. This is the decode_32k / long_500k hot spot: at 500k
context the op is pure HBM bandwidth (stream 2*S*D bytes of K/V per kv-head),
so the kernel's job is to keep the streaming dense and the softmax online.

TPU mapping: grid (batch*kv_head, s_block), s innermost; K/V stream through
VMEM one (block_s, D) tile per step; the G = H/KV query heads ride as rows of
a (G, D) VMEM-resident tile so the score matmul (G x D)@(D x block_s) feeds
the MXU. Accumulators (acc (G,D), m, l) carry in VMEM scratch across
s-blocks. Ring-buffer validity is a prefetched (block_s,) int mask.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float,
                   num_s_blocks: int):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                              # (G, D)
    k = k_ref[0]                              # (block_s, D)
    v = v_ref[0]
    valid = valid_ref[0]                      # (block_s,) int32
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where((valid > 0)[None, :], s, NEG)      # (G, block_s)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(sb == num_s_blocks - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_kernel(q, k_cache, v_cache, valid, *, block_s: int = 1024,
                        interpret: bool = False):
    """q: (B,1,H,D); k_cache,v_cache: (B,S,KV,D); valid: (B,S) bool.
    S % block_s == 0 (ops.py pads, padding marked invalid). -> (B,1,H,D)."""
    b, _, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    block_s = min(block_s, s)
    assert s % block_s == 0
    scale = 1.0 / math.sqrt(d)

    qr = q.reshape(b, kv, g, d).reshape(b * kv, g, d)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    vm = jnp.repeat(valid.astype(jnp.int32), kv, axis=0)     # (B*KV, S)

    grid = (b * kv, s // block_s)
    kernel = functools.partial(_decode_kernel, scale=scale,
                               num_s_blocks=s // block_s)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, sb: (bh, 0, 0)),
            pl.BlockSpec((1, block_s, d), lambda bh, sb: (bh, sb, 0)),
            pl.BlockSpec((1, block_s, d), lambda bh, sb: (bh, sb, 0)),
            pl.BlockSpec((1, block_s), lambda bh, sb: (bh, sb)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bh, sb: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, vm)
    return out.reshape(b, 1, h, d)
