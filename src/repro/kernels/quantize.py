"""Pallas TPU kernel for the transport layer's quantize/pack hot path.

``quantize_affine_kernel`` turns one tensor (flattened to (N, D)) into its
per-tensor affine int8 wire form: q = clip(round((x - xmin)/scale) - 128)
with (xmin, scale) computed over the VALID rows only — the row mask is how
the SelectedKnowledge codec keeps empty-cluster slots out of the statistics
(their bytes never cross the wire).

The global (xmin, xmax) must be known before any element can be quantized,
so the kernel runs a TWO-PHASE grid ``(2, N/block_n)``: TPU grids execute
sequentially with the last dimension fastest, so phase 0 streams every
n-block once and accumulates the masked min/max into a block-(0,0)-pinned
accumulator (the same read-modify-write-across-grid-steps pattern as the
fused Lloyd kernel's centroid sums), and phase 1 re-streams the blocks,
reads the finished accumulator, and writes the int8 payload. Two HBM reads
of x is the floor for exact per-tensor quantization; the (N, D) f32 -> int8
write is a 4x shrink, which is the point.

Row padding rides the mask (padded rows are masked out); column padding is
handled with a static ``d_true`` closed over by the kernel body (an iota
column guard), so zero-padded lanes never touch the statistics. Every
arithmetic step is an exact min/max reduction or an elementwise f32 op, so
the kernel is bit-identical to ``ref.quantize_affine_ref`` at any block
size, and the pallas_call vmaps across a stacked cohort of clients (the
batch axis becomes the outermost — slowest — grid dimension, so each
client's two phases still run in order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import BIG, affine_params_from_minmax


def _quantize_affine_kernel(d_true, x_ref, m_ref, q_ref, mm_ref):
    phase = pl.program_id(0)
    i = pl.program_id(1)
    x = x_ref[...]                             # (block_n, D)
    rm = m_ref[...]                            # (block_n, 128); col 0 = mask
    n_blk, dpad = x.shape
    rowok = rm[:, :1] > 0.0                    # (block_n, 1)
    colok = jax.lax.broadcasted_iota(jnp.int32, (n_blk, dpad), 1) < d_true
    valid = rowok & colok

    @pl.when(phase == 0)
    def _stats():
        # block min/max broadcast across the lanes: full-block accumulator
        # stores (no sub-tile scalar writes on the TPU path)
        bmin = jnp.full((1, 128), jnp.min(jnp.where(valid, x, BIG)),
                        jnp.float32)
        bmax = jnp.full((1, 128), jnp.max(jnp.where(valid, x, -BIG)),
                        jnp.float32)

        @pl.when(i == 0)
        def _init():
            mm_ref[...] = jnp.concatenate([bmin, bmax], axis=0)

        @pl.when(i > 0)
        def _accumulate():
            prev = mm_ref[...]
            mm_ref[...] = jnp.concatenate(
                [jnp.minimum(prev[0:1], bmin), jnp.maximum(prev[1:2], bmax)],
                axis=0)

    @pl.when(phase == 1)
    def _quantize():
        mm = mm_ref[...]
        xmin, scale = affine_params_from_minmax(mm[0, 0], mm[1, 0])
        # reciprocal multiply, matching the oracle op-for-op (see ref.py)
        q = jnp.clip(jnp.round((x - xmin) * (1.0 / scale)) - 128.0,
                     -128.0, 127.0)
        q_ref[...] = jnp.where(rowok, q, -128.0).astype(jnp.int8)


@functools.partial(jax.jit,
                   static_argnames=("d_true", "block_n", "interpret"))
def quantize_affine_kernel(x: jnp.ndarray, rowmask: jnp.ndarray, *,
                           d_true: int, block_n: int = 256,
                           interpret: bool = False):
    """x: (N, D) f32, rowmask: (N, 128) f32 (column 0 is the row's 0/1
    mask), N % block_n == 0, D lane-aligned with the first ``d_true``
    columns real (ops.quantize_affine handles padding). Returns
    (q (N, D) int8, minmax (2, 128) f32 with [0,0]=raw masked min and
    [1,0]=raw masked max — feed ``ref.affine_params_from_minmax``)."""
    n, d = x.shape
    assert n % block_n == 0, (n, block_n)
    assert rowmask.shape == (n, 128), rowmask.shape
    grid = (2, n // block_n)
    return pl.pallas_call(
        functools.partial(_quantize_affine_kernel, d_true),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda p, i: (i, 0)),   # stream x
            pl.BlockSpec((block_n, 128), lambda p, i: (i, 0)),  # stream mask
        ],
        out_specs=[
            pl.BlockSpec((block_n, d), lambda p, i: (i, 0)),
            pl.BlockSpec((2, 128), lambda p, i: (0, 0)),        # accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct((2, 128), jnp.float32),
        ],
        interpret=interpret,
    )(x, rowmask)
