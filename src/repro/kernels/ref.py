"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG = -1e30
BIG = 1e30


def kmeans_pairwise_dist_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(N,D),(K,D) -> (N,K) squared Euclidean distances."""
    x2 = jnp.sum(x * x, -1, keepdims=True)
    c2 = jnp.sum(c * c, -1)
    return x2 + c2[None, :] - 2.0 * (x @ c.T)


def kmeans_lloyd_ref(x: jnp.ndarray, c: jnp.ndarray, lmask: jnp.ndarray):
    """Oracle for the fused Lloyd step (kernels/kmeans.py).

    x: (N, D), c: (K, D), lmask: (N, K) additive mask — 0 where the row may
    join the cluster, BIG where forbidden. A row with no admissible cluster
    gets zero weight in the statistics. Returns
    (assign (N,) i32, mindist (N,) f32, sums (K, D) f32, counts (K,) f32).
    """
    k = c.shape[0]
    d = kmeans_pairwise_dist_ref(x, c) + lmask
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    mind = jnp.min(d, axis=1)
    w = (jnp.min(lmask, axis=1) <= 0.0).astype(x.dtype)
    onehot = jax.nn.one_hot(assign, k, dtype=x.dtype) * w[:, None]
    counts = onehot.sum(0)                                 # (K,)
    sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    return assign, mind, sums, counts


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """(B,S,H,D) x (B,S,KV,D)^2 -> (B,S,H,D); GQA via head repeat."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    s = s / math.sqrt(d)
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qi >= ki
    if window > 0:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None, None], s, NEG)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v)


def flash_decode_ref(q, k_cache, v_cache, valid):
    """q:(B,1,H,D) caches:(B,S,KV,D) valid:(B,S) -> (B,1,H,D)."""
    b, _, h, d = q.shape
    kv = k_cache.shape[2]
    rep = h // kv
    if rep > 1:
        k_cache = jnp.repeat(k_cache, rep, axis=2)
        v_cache = jnp.repeat(v_cache, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache).astype(jnp.float32)
    s = s / math.sqrt(d)
    s = jnp.where(valid[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v_cache)
