"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG = -1e30
BIG = 1e30


def kmeans_pairwise_dist_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(N,D),(K,D) -> (N,K) squared Euclidean distances."""
    x2 = jnp.sum(x * x, -1, keepdims=True)
    c2 = jnp.sum(c * c, -1)
    return x2 + c2[None, :] - 2.0 * (x @ c.T)


def kmeans_lloyd_ref(x: jnp.ndarray, c: jnp.ndarray, lmask: jnp.ndarray):
    """Oracle for the fused Lloyd step (kernels/kmeans.py).

    x: (N, D), c: (K, D), lmask: (N, K) additive mask — 0 where the row may
    join the cluster, BIG where forbidden. A row with no admissible cluster
    gets zero weight in the statistics. Returns
    (assign (N,) i32, mindist (N,) f32, sums (K, D) f32, counts (K,) f32).
    """
    k = c.shape[0]
    d = kmeans_pairwise_dist_ref(x, c) + lmask
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    mind = jnp.min(d, axis=1)
    w = (jnp.min(lmask, axis=1) <= 0.0).astype(x.dtype)
    onehot = jax.nn.one_hot(assign, k, dtype=x.dtype) * w[:, None]
    counts = onehot.sum(0)                                 # (K,)
    sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    return assign, mind, sums, counts


def quantize_affine_ref(x: jnp.ndarray, rowmask: jnp.ndarray):
    """Oracle for the fused per-tensor affine int8 quantizer
    (kernels/quantize.py) — the transport layer's SelectedKnowledge pack
    hot path.

    x: (N, D) f32, rowmask: (N,) bool/0-1 — statistics (min/max) run over
    VALID rows only; masked rows quantize to -128 deterministically (their
    values never cross the wire — the codec packs valid rows only — but the
    kernel/oracle bit-for-bit contract covers them).

    Returns (q (N, D) int8, xmin f32 scalar, scale f32 scalar) with the
    dequantization contract ``x_hat = (q + 128) * scale + xmin``:
      * scale = (xmax - xmin) / 255 over valid rows
      * constant tensors (xmax == xmin) use scale=1 -> q = -128 everywhere
        and x_hat == xmin EXACTLY
      * an all-masked payload yields xmin=0, scale=1 (nothing to transmit,
        but the params stay finite for framing)
    Every step is elementwise f32 or an exact min/max reduction, so the
    Pallas kernel reproduces it bit-for-bit at any block size."""
    valid = rowmask.astype(bool)[:, None]
    xmin_raw = jnp.min(jnp.where(valid, x, BIG))
    xmax_raw = jnp.max(jnp.where(valid, x, -BIG))
    xmin, scale = affine_params_from_minmax(xmin_raw, xmax_raw)
    # multiply by the reciprocal EXPLICITLY: XLA strength-reduces a
    # vector/scalar division to a reciprocal multiply in some fusions but
    # not others, which would cost the kernel/oracle bit-identity at
    # round-half boundaries; one scalar reciprocal is deterministic
    q = jnp.clip(jnp.round((x - xmin) * (1.0 / scale)) - 128.0,
                 -128.0, 127.0)
    q = jnp.where(valid, q, -128.0).astype(jnp.int8)
    return q, xmin, scale


def affine_params_from_minmax(xmin_raw, xmax_raw):
    """(raw masked min, raw masked max) -> (xmin, scale) of the affine int8
    contract. Shared by the oracle, the Pallas kernel's quantize phase, and
    the ops wrapper (which receives the kernel's raw accumulators), so all
    three compute the identical f32 expression."""
    has = xmax_raw >= xmin_raw
    xmin = jnp.where(has, xmin_raw, 0.0).astype(jnp.float32)
    rng = jnp.where(has, xmax_raw - xmin, 0.0)
    # an explicit multiply, NOT rng/255: XLA strength-reduces division by a
    # constant to a reciprocal multiply only in some compilation contexts
    # (fused jit vs eager vs interpret), which would let the same payload
    # produce two different scales — and two different wire encodings
    scale = jnp.where(rng > 0, rng * jnp.float32(1.0 / 255.0),
                      1.0).astype(jnp.float32)
    return xmin, scale


def dequantize_affine_ref(q: jnp.ndarray, xmin, scale) -> jnp.ndarray:
    """Inverse of ``quantize_affine_ref``: x_hat = (q + 128) * scale + xmin
    (f32). |x_hat - x| <= scale/2 for every valid row element."""
    return (q.astype(jnp.float32) + 128.0) * jnp.float32(scale) \
        + jnp.float32(xmin)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """(B,S,H,D) x (B,S,KV,D)^2 -> (B,S,H,D); GQA via head repeat."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    s = s / math.sqrt(d)
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qi >= ki
    if window > 0:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None, None], s, NEG)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v)


def flash_decode_ref(q, k_cache, v_cache, valid):
    """q:(B,1,H,D) caches:(B,S,KV,D) valid:(B,S) -> (B,1,H,D)."""
    b, _, h, d = q.shape
    kv = k_cache.shape[2]
    rep = h // kv
    if rep > 1:
        k_cache = jnp.repeat(k_cache, rep, axis=2)
        v_cache = jnp.repeat(v_cache, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache).astype(jnp.float32)
    s = s / math.sqrt(d)
    s = jnp.where(valid[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v_cache)
