"""Pallas TPU kernel: blocked causal (optionally sliding-window) flash
attention — the prefill hot spot of every attention architecture here
(gemma3's 5:1 local:global pattern makes the windowed path the common case).

TPU mapping: 3-D grid (batch*kv_head, q_block, k_block) with the k_block axis
innermost and marked 'arbitrary' so the f32 accumulators (acc, m, l) carry in
VMEM scratch across k-blocks (the online-softmax recurrence). Each grid cell
does two MXU matmuls: (block_q*G x D)@(D x block_k) for scores and
(block_q*G x block_k)@(block_k x D) for the value gather, where G = q-heads
per kv-head (GQA folded into the row dimension so the MXU tile stays full).
Causal + window masking is VPU select; fully-masked blocks short-circuit via
@pl.when on the block index comparison.

VMEM per cell (f32): block_q*G*D + 2*block_k*D + block_q*G*block_k + scratch.
Defaults (block_q=block_k=512, D=128, G=8): ~5 MB — inside the 16 MB/core v5e
budget with double buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, seq_len: int,
                  causal: bool, window: int, num_k_blocks: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qb * block_q
    k_start = kb * block_k

    # block-level reachability: q rows [q_start, q_start+bq), k cols
    # [k_start, k_start+bk); skip if entirely masked
    reachable = True
    if causal:
        reachable = q_start + block_q - 1 >= k_start
    in_window = True
    if window > 0:
        in_window = q_start < k_start + block_k + window

    @pl.when(jnp.logical_and(reachable, in_window))
    def _compute():
        q = q_ref[0]                          # (block_q*G, D)
        k = k_ref[0]                          # (block_k, D)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        g = q.shape[0] // block_q             # GQA group folded into rows
        qi = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], block_k), 0) // g
        ki = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], block_k), 1)
        mask = ki < seq_len
        if causal:
            mask &= qi >= ki
        if window > 0:
            mask &= (qi - ki) < window
        s = jnp.where(mask, s, NEG)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == num_k_blocks - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False):
    """q: (B,S,H,D); k,v: (B,S,KV,D); H % KV == 0; S % block == 0 (ops.py pads).
    Returns (B,S,H,D)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    scale = 1.0 / math.sqrt(d)

    # layout: fold GQA group into q rows: (B*KV, S*G? ) — keep (B*KV, S, G*D)?
    # Simplest robust layout: (B*KV, S, G, D) -> rows (S_block*G, D)
    qr = q.reshape(b, s, kv, g, d).transpose(0, 2, 1, 3, 4).reshape(
        b * kv, s * g, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, s, d)

    num_q_blocks = s // block_q
    num_k_blocks = s // block_k
    grid = (b * kv, num_q_blocks, num_k_blocks)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=s, causal=causal, window=window, num_k_blocks=num_k_blocks)

    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q * g, d), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qb, kb: (bh, kb, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qb, kb: (bh, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q * g, d),
                               lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, s * g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, d), jnp.float32),   # acc
            pltpu.VMEM((block_q * g, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q * g, 1), jnp.float32),   # running sum l
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, kv, s, g, d).transpose(0, 2, 1, 3, 4).reshape(
        b, s, h, d)
