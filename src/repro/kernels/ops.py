"""jit'd public wrappers around the Pallas kernels: shape padding/alignment,
CPU interpret-mode fallback (this container), and the dispatch points the
model/selection code calls.

Each dispatch site sits in an ``obs.timed_block`` span (a no-op when
``FLConfig.observability`` is off). These wrappers usually run INSIDE a
jit trace, where ``sp.sync`` sees abstract tracers: it then skips
``block_until_ready`` and marks the span ``traced`` (the time measured is
trace/compile time, not device time — kernel spans with ``traced`` absent
are real eager dispatches, block-until-ready-synced so async device work
is counted)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.kmeans import kmeans_lloyd_kernel, kmeans_pairwise_dist_kernel
from repro.kernels.quantize import quantize_affine_kernel
from repro.obs.profile import profiled_jit

# profiled entries (module-level, so the signature caches live across
# rounds — the recompilation sentinel counts every *new* signature, and
# traced eager dispatches get {flops, hbm_bytes, utilization} attached to
# the enclosing kernel.* span)
_pdist = profiled_jit(kmeans_pairwise_dist_kernel,
                      name="kmeans_pairwise_dist_kernel",
                      static_argnames=("block_n", "interpret"))
_lloyd = profiled_jit(kmeans_lloyd_kernel, name="kmeans_lloyd_kernel",
                      static_argnames=("block_n", "interpret"))
_quant = profiled_jit(quantize_affine_kernel, name="quantize_affine_kernel",
                      static_argnames=("d_true", "block_n", "interpret"))
_flash = profiled_jit(flash_attention_kernel, name="flash_attention_kernel",
                      static_argnames=("causal", "window", "block_q",
                                       "block_k", "interpret"))
_decode = profiled_jit(flash_decode_kernel, name="flash_decode_kernel",
                       static_argnames=("block_s", "interpret"))


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def kmeans_pairwise_dist(x: jnp.ndarray, c: jnp.ndarray,
                         block_n: int = 256) -> jnp.ndarray:
    """(N,D),(K,D) -> (N,K). Pads N to block_n, D and K to lane width 128.
    Distance is padding-invariant in D (zeros contribute 0); padded centroids
    are sliced away; padded rows dropped."""
    n, d = x.shape
    k = c.shape[0]
    if n < 64:   # tiny problems: the jnp path is faster than kernel overhead
        return ref.kmeans_pairwise_dist_ref(x, c)
    npad = _pad_to(n, block_n)
    dpad = _pad_to(d, 128)
    kpad = _pad_to(k, 128)
    xp = jnp.pad(x.astype(jnp.float32), ((0, npad - n), (0, dpad - d)))
    cp = jnp.pad(c.astype(jnp.float32), ((0, kpad - k), (0, dpad - d)))
    with obs.timed_block("kernel.kmeans_pairwise_dist",
                         n=n, d=d, k=k) as sp:
        out = sp.sync(_pdist(xp, cp, block_n=block_n,
                             interpret=_interpret()))
    return out[:n, :k]


def kmeans_lloyd_step(x: jnp.ndarray, c: jnp.ndarray, lmask: jnp.ndarray,
                      block_n: int = 256):
    """Fused Lloyd step: (N,D),(K,D),(N,K) -> (assign (N,) i32,
    mindist (N,), sums (K,D), counts (K,)). Pads N to block_n and D/K to
    lane width 128. Padding is correctness-free by construction: padded
    rows get an all-BIG mask row (zero weight, never accumulated), padded
    cluster columns get BIG for every row (never win the argmin), and
    zero-padded D contributes 0 to every distance."""
    n, d = x.shape
    k = c.shape[0]
    if n < 64:   # tiny problems: the jnp path is faster than kernel overhead
        return ref.kmeans_lloyd_ref(x, c, lmask)
    npad = _pad_to(n, block_n)
    dpad = _pad_to(d, 128)
    kpad = _pad_to(k, 128)
    xp = jnp.pad(x.astype(jnp.float32), ((0, npad - n), (0, dpad - d)))
    cp = jnp.pad(c.astype(jnp.float32), ((0, kpad - k), (0, dpad - d)))
    lp = jnp.pad(lmask.astype(jnp.float32), ((0, npad - n), (0, kpad - k)),
                 constant_values=ref.BIG)
    with obs.timed_block("kernel.kmeans_lloyd_step", n=n, d=d, k=k) as sp:
        assign, mind, sums, counts = sp.sync(_lloyd(
            xp, cp, lp, block_n=block_n, interpret=_interpret()))
    return assign[:n], mind[:n], sums[:k, :d], counts[0, :k]


def quantize_affine(x: jnp.ndarray, rowmask: jnp.ndarray,
                    block_n: int = 256):
    """Per-tensor affine int8 quantization of (N, D) x with (N,) row mask
    (the transport codec's pack hot path). Pads N to block_n and D to lane
    width 128; padded rows are masked out of the statistics and padded
    columns are guarded by the kernel's static d_true, so padding is
    correctness-free. Returns (q (N, D) int8, xmin f32, scale f32) exactly
    matching ``ref.quantize_affine_ref`` bit-for-bit (vmappable across a
    stacked cohort — the batch axis becomes the outermost grid dim)."""
    n, d = x.shape
    if n < 64:   # tiny payloads: the jnp path beats kernel dispatch
        return ref.quantize_affine_ref(x, rowmask)
    npad = _pad_to(n, block_n)
    dpad = _pad_to(d, 128)
    xp = jnp.pad(x.astype(jnp.float32), ((0, npad - n), (0, dpad - d)))
    mp = jnp.pad(rowmask.astype(jnp.float32), (0, npad - n))
    mp = jnp.broadcast_to(mp[:, None], (npad, 128))
    with obs.timed_block("kernel.quantize_affine", n=n, d=d) as sp:
        q, mm = sp.sync(_quant(xp, mp, d_true=d, block_n=block_n,
                               interpret=_interpret()))
    xmin, scale = ref.affine_params_from_minmax(mm[0, 0], mm[1, 0])
    return q[:n, :d], xmin, scale


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512) -> jnp.ndarray:
    """(B,S,H,D) GQA flash attention. Pads S to block multiples (key padding
    masked by seq_len inside the kernel; query padding rows sliced away) and
    D to 128 lanes (zero-padded D leaves logits unchanged)."""
    b, s, h, d = q.shape
    blk = min(block_q, block_k, _pad_to(s, 128))
    spad = _pad_to(s, blk)
    dpad = _pad_to(d, 128)
    pad4 = lambda t: jnp.pad(t, ((0, 0), (0, spad - s), (0, 0), (0, dpad - d)))
    qp, kp, vp = pad4(q), pad4(k), pad4(v)
    # scale uses original d: kernel scales by 1/sqrt(dpad) — compensate
    qp = qp * (dpad / d) ** 0.5
    with obs.timed_block("kernel.flash_attention", b=b, s=s, h=h,
                         d=d) as sp:
        out = sp.sync(_flash(
            qp, kp, vp, causal=causal, window=window,
            block_q=min(block_q, spad), block_k=min(block_k, spad),
            interpret=_interpret()))
    return out[:, :s, :, :d]


def flash_decode(q, k_cache, v_cache, valid, *, block_s: int = 1024
                 ) -> jnp.ndarray:
    """(B,1,H,D) x (B,S,KV,D) ring-buffer decode attention."""
    b, _, h, d = q.shape
    s = k_cache.shape[1]
    blk = min(block_s, _pad_to(s, 128))
    spad = _pad_to(s, blk)
    dpad = _pad_to(d, 128)
    padc = lambda t: jnp.pad(t, ((0, 0), (0, spad - s), (0, 0), (0, dpad - d)))
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, dpad - d))) * (dpad / d) ** 0.5
    kp, vp = padc(k_cache), padc(v_cache)
    vm = jnp.pad(valid, ((0, 0), (0, spad - s)))
    with obs.timed_block("kernel.flash_decode", b=b, s=s, h=h, d=d) as sp:
        out = sp.sync(_decode(qp, kp, vp, vm, block_s=blk,
                              interpret=_interpret()))
    return out[..., :d]
