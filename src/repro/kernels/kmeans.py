"""Pallas TPU kernel: K-means pairwise squared-distance (the paper's §3.1
selection hot spot — run every round on every client over all local samples).

TPU mapping: ||x-c||^2 = ||x||^2 + ||c||^2 - 2 x.c — the -2x.c term is a
(block_n x D) @ (D x K) matmul on the MXU; the norms ride on the VPU. The
full centroid set (K x D) is VMEM-resident across the whole grid (index_map
pins it to block (0,0)); x is streamed HBM->VMEM one n-block at a time.

Alignment: D and K are padded by ops.py to lane multiples (128); block_n is a
sublane multiple (8 for f32). VMEM claim per grid cell:
  block_n*D + K*D + block_n*K floats  (e.g. 256*256 + 128*256 + 256*128 ≈ 0.5 MB)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_dist_kernel(x_ref, c_ref, out_ref):
    x = x_ref[...]                           # (block_n, D)
    c = c_ref[...]                           # (K, D)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)            # (block_n, 1)
    c2 = jnp.sum(c * c, axis=1)                           # (K,)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    out_ref[...] = x2 + c2[None, :] - 2.0 * xc


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_pairwise_dist_kernel(x: jnp.ndarray, c: jnp.ndarray,
                                block_n: int = 256,
                                interpret: bool = False) -> jnp.ndarray:
    """x: (N, D) f32, c: (K, D) f32, N % block_n == 0, D/K lane-aligned
    (ops.kmeans_pairwise_dist handles padding). Returns (N, K) f32."""
    n, d = x.shape
    k = c.shape[0]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _kmeans_dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # stream x blocks
            pl.BlockSpec((k, d), lambda i: (0, 0)),         # centroids resident
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(x, c)
