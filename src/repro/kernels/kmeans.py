"""Pallas TPU kernels for the paper's §3.1 selection hot spot (run every
round on every client over all local samples).

Two kernels:

1. ``kmeans_pairwise_dist_kernel`` — the original distance-matrix kernel:
   ||x-c||^2 = ||x||^2 + ||c||^2 - 2 x.c. The -2x.c term is a
   (block_n x D) @ (D x K) matmul on the MXU; the norms ride on the VPU.

2. ``kmeans_lloyd_kernel`` — the fused Lloyd step. One HBM pass per sweep:
   for each n-block it computes the biased distance tile
   d = ||x||^2 + ||c||^2 - 2 x.c + lmask, takes the row argmin (assignment),
   and accumulates the masked per-cluster statistics sum_j x and count_j on
   the spot — so the (N, K) distance matrix is never materialized in HBM and
   never re-read through a one_hot matmul. ``lmask`` is an additive mask
   (0 = row may join cluster, BIG = forbidden); it encodes both invalid rows
   (whole row BIG -> zero weight) and the per-class cluster structure of
   select_metadata (a row only sees its own class's cluster columns), which
   is what lets one kernel sweep replace ``num_classes`` masked sweeps.

Grid layout (both kernels): 1-D grid over n-blocks, ``grid = (N / block_n,)``.
The centroid set (K x D) is VMEM-resident across the whole grid (index_map
pins it to block (0,0)); x and lmask are streamed HBM->VMEM one n-block at a
time. The fused kernel's accumulator outputs (sums (K, D), counts (1, K))
are also pinned to block (0,0); TPU grids execute sequentially, so the
read-modify-write accumulation across grid steps is safe (initialized at
grid step 0 via ``pl.when``).

Alignment: D and K are padded by ops.py to lane multiples (128); block_n is
a sublane multiple (8 for f32, default 256). VMEM claim per grid cell of the
fused kernel, in f32 words:

    x        block_n * D
    c        K * D          (resident)
    lmask    block_n * K
    sums     K * D          (resident accumulator)
    counts   K
    assign   block_n        (int32)
    mindist  block_n
    + the (block_n, K) distance / one-hot intermediates.

At the paper-scale operating point (block_n=256, D=128, K=128 after
padding: 2500 maps, P=64 PCA dims, 10 classes x 10 clusters) that is
~0.45 MB — far under the ~16 MB/core budget, leaving room for the
pipeline's double buffering; block_n can grow to 2048 before VMEM matters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_dist_kernel(x_ref, c_ref, out_ref):
    x = x_ref[...]                           # (block_n, D)
    c = c_ref[...]                           # (K, D)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)            # (block_n, 1)
    c2 = jnp.sum(c * c, axis=1)                           # (K,)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    out_ref[...] = x2 + c2[None, :] - 2.0 * xc


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_pairwise_dist_kernel(x: jnp.ndarray, c: jnp.ndarray,
                                block_n: int = 256,
                                interpret: bool = False) -> jnp.ndarray:
    """x: (N, D) f32, c: (K, D) f32, N % block_n == 0, D/K lane-aligned
    (ops.kmeans_pairwise_dist handles padding). Returns (N, K) f32."""
    n, d = x.shape
    k = c.shape[0]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _kmeans_dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # stream x blocks
            pl.BlockSpec((k, d), lambda i: (0, 0)),         # centroids resident
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(x, c)


def _kmeans_lloyd_kernel(x_ref, c_ref, m_ref,
                         assign_ref, mind_ref, sums_ref, counts_ref):
    i = pl.program_id(0)
    x = x_ref[...]                            # (block_n, D)
    c = c_ref[...]                            # (K, D)
    lm = m_ref[...]                           # (block_n, K) additive mask
    n_blk, k = lm.shape

    x2 = jnp.sum(x * x, axis=1, keepdims=True)             # (block_n, 1)
    c2 = jnp.sum(c * c, axis=1)                            # (K,)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d = x2 + c2[None, :] - 2.0 * xc + lm                   # biased distances

    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    assign_ref[...] = assign
    mind_ref[...] = jnp.min(d, axis=1)

    # a row with no admissible cluster (min mask > 0) gets zero weight
    w = (jnp.min(lm, axis=1) <= 0.0).astype(jnp.float32)   # (block_n,)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n_blk, k), 1)
    onehot = (assign[:, None] == cols).astype(jnp.float32) * w[:, None]
    bsums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (K, D)
    bcounts = jnp.sum(onehot, axis=0)[None, :]             # (1, K)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = bsums
        counts_ref[...] = bcounts

    @pl.when(i > 0)
    def _accumulate():
        sums_ref[...] += bsums
        counts_ref[...] += bcounts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_lloyd_kernel(x: jnp.ndarray, c: jnp.ndarray, lmask: jnp.ndarray,
                        block_n: int = 256, interpret: bool = False):
    """Fused Lloyd step. x: (N, D) f32, c: (K, D) f32, lmask: (N, K) f32
    additive mask, N % block_n == 0, D/K lane-aligned (ops.kmeans_lloyd_step
    handles padding). Returns (assign (N,) i32, mindist (N,) f32,
    sums (K, D) f32, counts (1, K) f32)."""
    n, d = x.shape
    k = c.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert lmask.shape == (n, k), (lmask.shape, n, k)
    grid = (n // block_n,)
    return pl.pallas_call(
        _kmeans_lloyd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # stream x blocks
            pl.BlockSpec((k, d), lambda i: (0, 0)),         # centroids resident
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),   # stream mask blocks
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),         # accumulator
            pl.BlockSpec((1, k), lambda i: (0, 0)),         # accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
        ],
        interpret=interpret,
    )(x, c, lmask)
