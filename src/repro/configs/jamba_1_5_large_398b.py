"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e
top-2. [arXiv:2403.19887; assignment row: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2]

Superblock = 8 layers: 7 mamba + 1 attention (1:7); MoE replaces the dense
FFN every 2nd layer (moe_layer_period=2). long_500k RUNS: mamba layers carry
constant state; the 9 attention layers carry the full KV cache (sequence-
sharded — see DESIGN.md §6)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,                    # per-expert width (and dense-FFN width)
    vocab_size=65_536,
    num_experts=16,
    num_experts_per_tok=2,
    moe_layer_period=2,
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
    rope_theta=0.0,                # jamba: no positional encoding on attn layers
    tie_embeddings=False,
    long_context_mode="native",
)
