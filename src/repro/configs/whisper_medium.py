"""whisper-medium [audio] — encoder-decoder; mel-spectrogram + conv frontend
STUBBED (input_specs provides precomputed frame embeddings, 1500 frames).
[arXiv:2212.04356; assignment row: 24L d_model=1024 16H (kv=16) d_ff=4096
vocab=51865]

long_500k is SKIPPED for this arch (DESIGN.md §5): the family's decoder
context envelope (448 learned positions; 1500-frame encoder) does not extend
to 524k decode positions. decode_32k exercises the decoder self-attention KV
cache + cross-attention to the stubbed encoder output.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    source="arXiv:2212.04356",
    num_layers=24,                 # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,               # MHA
    d_ff=4096,
    vocab_size=51_865,             # padded to 51968
    is_encoder_decoder=True,
    encoder_seq_len=1500,
    frontend="audio_stub",
    act="gelu",
    rope_theta=0.0,                # whisper uses absolute positions (sinusoidal here)
    tie_embeddings=True,
    long_context_mode="skip",
)
