"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8 routing, GQA.
[hf:Qwen/Qwen3-30B-A3B; assignment row: 48L d_model=2048 32H (GQA kv=4)
d_ff=768(per expert) vocab=151936, MoE 128e top-8]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                      # per-expert FFN width
    vocab_size=151_936,
    num_experts=128,
    num_experts_per_tok=8,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    long_context_mode="swa",
)
