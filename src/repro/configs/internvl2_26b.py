"""internvl2-26b [vlm] — InternViT vision frontend (STUBBED: input_specs
provides precomputed patch embeddings) + InternLM2 decoder backbone.
[arXiv:2404.16821; assignment row: 48L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=92553]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    source="arXiv:2404.16821",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92_553,             # padded to 92672 for model-axis sharding
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    frontend="vision_stub",
    num_prefix_tokens=256,         # ViT patch tokens prepended to the text seq
    long_context_mode="swa",
)
