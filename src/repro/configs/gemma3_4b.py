"""gemma3-4b [dense] — 5:1 local:global sliding-window attention, 128k ctx.
[hf:google/gemma-3-1b-pt family card; assignment row: 34L d_model=2560 8H
(GQA kv=4) d_ff=10240 vocab=262144]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    sliding_window=1024,
    local_global_pattern=(5, 1),   # 5 local layers then 1 global, repeating
    rope_theta=1_000_000.0,
    act="gelu",
    tie_embeddings=True,
    long_context_mode="native",    # SWA is native -> long_500k runs as-is
)
