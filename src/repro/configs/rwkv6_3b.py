"""rwkv6-3b [ssm] — Finch: attention-free linear recurrence with
data-dependent decay. [arXiv:2404.05892; assignment row: 32L d_model=2560
(attn-free) d_ff=8960 vocab=65536]

long_500k RUNS natively (constant-size recurrent state decode)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    num_heads=40,                  # wkv heads, head_dim 64
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    block_pattern=("rwkv",),
    tie_embeddings=False,
    long_context_mode="state",
)
