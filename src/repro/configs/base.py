"""Config system: ModelConfig (architecture), ShapeConfig (input shape),
TrainConfig / FLConfig (the paper's federated-split-training knobs).

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` built from :class:`ModelConfig`. ``reduced()`` derives the smoke-test
variant (<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

VOCAB_PAD = 256  # Megatron-style vocab padding so the vocab dim shards cleanly.


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per assigned architecture.

    ``layer_kinds`` describes the repeating block pattern:
      - dense / moe LMs:     ("attn",) * L                     (scan, homogeneous)
      - rwkv6:               ("rwkv",) * L
      - jamba superblock:    ("mamba",)*7 + ("attn",)  x (L//8) (scan over superblocks)
    Gemma3's 5-local:1-global pattern is data, not structure: the per-layer
    sliding window size rides through the scan as a stacked scalar.
    """

    name: str
    arch_type: str                     # dense|moe|ssm|hybrid|vlm|audio
    source: str                        # citation bracket from the assignment
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads

    # --- attention ---
    attention_kind: str = "gqa"        # gqa | mla
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0            # 0 = full attention
    local_global_pattern: Tuple[int, int] = (0, 1)  # (local, global) per repeat; gemma3=(5,1)
    swa_variant_window: int = 4096     # window used when forcing SWA for long_500k

    # --- MLA (deepseek-v2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_layer_period: int = 1          # MoE every k-th layer (jamba: 2); dense FFN otherwise
    first_dense_layers: int = 0        # deepseek-v2: first layer is dense FFN
    router_aux_loss: float = 0.001

    # --- SSM (mamba / rwkv6) ---
    block_pattern: Tuple[str, ...] = ("attn",)   # repeating kinds; len divides num_layers
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2

    # --- embeddings / head ---
    tie_embeddings: bool = True
    pad_vocab: bool = True

    # --- modality frontend stubs ---
    frontend: Optional[str] = None     # None | "vision_stub" | "audio_stub"
    num_prefix_tokens: int = 0         # vlm: patch-embedding tokens prepended
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0           # whisper: 1500 frames

    # --- norm / act ---
    norm_eps: float = 1e-6
    act: str = "silu"                  # silu (swiglu) | gelu

    # --- long_500k policy ---
    long_context_mode: str = "swa"     # native|swa|state|skip (see DESIGN.md §5)

    # --- perf knobs (hillclimb axes, see EXPERIMENTS.md §Perf) ---
    mla_absorbed: bool = False         # MLA decode in latent space (deepseek)

    # --- the paper: split point as a fraction of depth (layer j) ---
    split_fraction: float = 0.5

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_layers % len(self.block_pattern) == 0, (
            self.name, self.num_layers, self.block_pattern)

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, VOCAB_PAD) if self.pad_vocab else self.vocab_size

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:          # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def split_layer(self) -> int:
        """Layer index j at which the paper splits lower/upper."""
        j = int(round(self.num_layers * self.split_fraction))
        return max(1, min(self.num_layers - 1, j))

    def layer_kinds(self) -> Tuple[str, ...]:
        reps = self.num_layers // len(self.block_pattern)
        return tuple(self.block_pattern) * reps

    def window_sizes(self, seq_len: int, force_swa: bool = False) -> Tuple[int, ...]:
        """Per-attention-layer sliding windows (0 = full). Data, not structure."""
        loc, glob = self.local_global_pattern
        out = []
        n_attn = sum(1 for k in self.layer_kinds() if k == "attn")
        for i in range(n_attn):
            if force_swa:
                # long_500k SWA variant: every attention layer windowed.
                w = self.sliding_window or self.swa_variant_window
            elif loc > 0:
                w = self.sliding_window if (i % (loc + glob)) < loc else 0
            else:
                w = self.sliding_window
            out.append(w)
        return tuple(out)

    def num_params(self) -> int:
        """Analytic parameter count (used for 6ND roofline terms)."""
        from repro.models.registry import count_params  # lazy, avoids cycle
        return count_params(self)

    def active_params(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (see brief: <=2 layers,
        d_model<=512, <=4 experts)."""
        pat = self.block_pattern
        nl = len(pat) if len(pat) > 1 else 2
        d_model = min(self.d_model, 128)
        heads = max(2, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, 2))
        changes = dict(
            num_layers=nl,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=32,
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq_len=min(self.encoder_seq_len, 16),
            num_prefix_tokens=min(self.num_prefix_tokens, 8),
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            swa_variant_window=16,
        )
        if self.is_moe:
            changes.update(num_experts=4, num_experts_per_tok=2,
                           num_shared_experts=min(self.num_shared_experts, 1))
        if self.attention_kind == "mla":
            changes.update(kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=32,
                           qk_rope_head_dim=16, v_head_dim=32, head_dim=48)
        return replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class FLConfig:
    """The paper's knobs (Table 3/4/7 hyperparameters)."""
    num_clients: int = 20
    clients_per_round: int = 20
    local_epochs: int = 1
    local_batch_size: int = 50
    local_lr: float = 0.1
    # selection (Section 3.1)
    pca_components: int = 200
    clusters_per_class: int = 10
    kmeans_iters: int = 25
    select_per_cluster: int = 1
    # meta-training (Section 3.3)
    meta_epochs: int = 2
    meta_batch_size: int = 50
    meta_lr: float = 0.1
    meta_l2: float = 0.0               # Table 7: 0 / 5e-4 / 1e-3
    reset_upper_each_round: bool = True  # paper: always trains from W_G^u(0)
    split_fraction: float = 0.34       # WRN-40-1 group 1 of 3
    use_selection: bool = True         # False = Table 2 baseline (all maps)
    # --- selection engine knobs (beyond-paper perf; defaults = seed math) ---
    batched_selection: bool = True     # vmap Extract&Selection across cohort
    pca_solver: str = "exact"          # "randomized" = range-finder fast path
    use_pallas_selection: bool = False # fused Pallas Lloyd kernel (TPU)
    # --- pod-scale engine (repro.core.distributed; results bit-identical) ---
    distributed_selection: bool = False  # stacked cohort_round + shard_map
    selection_chunk_size: int = 0      # >0: stream cohorts this many clients
                                       # at a time (0 = auto by memory budget)
    # --- transport (repro.fl.transport; every ledger entry = exact bytes) ---
    transport_codec: str = "raw_f32"   # SelectedKnowledge codec:
                                       # raw_f32 | f16 | int8 (Pallas
                                       # quantize when use_pallas_selection)
    transport_checksum: bool = False   # CRC32 trailer on every frame (wire
                                       # v2 flags bit 0; +4B/frame). Off by
                                       # default so fault-free ledgers stay
                                       # byte-identical to the pre-CRC wire;
                                       # chaos runs turn it on to make every
                                       # in-flight corruption detectable.
    # --- observability (repro.obs; span trace + metrics + kernel timing) ---
    observability: bool = False        # off: every obs hook is a NullTracer
                                       # no-op and runs stay bit-identical,
                                       # ledger included. On: FLSimulation
                                       # owns a Tracer (sim.tracer) emitting
                                       # schema-versioned JSONL; see
                                       # `python -m repro.obs` and README
                                       # "Observability".


@dataclass(frozen=True)
class TrainConfig:
    """Distributed training-step config for the pod runtime."""
    local_steps: int = 2               # L local SGD steps between FedAvg syncs
    microbatch: int = 8                # tokens rows per grad-accum microstep
    lr: float = 0.1
    momentum: float = 0.0
    weight_decay: float = 0.0
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    fed_axis: str = "data"             # mesh axis hosting client cohorts
    remat: bool = True
    # paper technique in the lowered step:
    split_fl: bool = True              # lower=FedAvg, upper=metadata-trained
    meta_clusters: int = 8             # clusters per cohort for selection
    meta_steps: int = 2                # server-side upper-training steps
    pca_components: int = 64
    # --- beyond-paper perf knobs (EXPERIMENTS.md §Perf) ---
    seq_shard_activations: bool = False  # hidden states P(None,'model',None)
    fedavg_compress: str = ""            # "" | "bf16" (delta all-reduce dtype)
