"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared / 160 routed top-6.
[arXiv:2405.04434; assignment row: 60L d_model=5120 128H d_ff=1536(per expert)
vocab=102400, MoE 160e top-6]

long_500k runs in SWA-variant mode for the dry-run: MLA decode over the
compressed (kv_lora+rope)-dim cache is O(T) per token; the cache is sequence-
sharded over the data axis."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,              # MLA: per-head keys reconstructed from latent
    head_dim=192,                  # qk_nope(128)+qk_rope(64)
    d_ff=1536,                     # per routed expert
    vocab_size=102_400,
    attention_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=160,
    num_experts_per_tok=6,
    num_shared_experts=2,
    first_dense_layers=1,
    tie_embeddings=False,
    long_context_mode="swa",
)
