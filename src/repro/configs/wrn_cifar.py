"""The paper's own model: WRN-40-1 on CIFAR-10 (Zagoruyko & Komodakis,
arXiv:1605.07146). 3 groups x 6 basic blocks, widen factor 1; split after
group 1 (activation maps 16x32x32) per the paper §4.1 / [18]."""
from dataclasses import dataclass


@dataclass(frozen=True)
class WRNConfig:
    name: str = "wrn-40-1"
    depth: int = 40                # (40-4)/6 = 6 blocks per group
    widen: int = 1
    num_classes: int = 10
    image_size: int = 32
    channels: int = 3
    split_group: int = 1           # paper: split after group 1 -> maps 16x32x32

    @property
    def blocks_per_group(self) -> int:
        assert (self.depth - 4) % 6 == 0
        return (self.depth - 4) // 6

    def reduced(self) -> "WRNConfig":
        return WRNConfig(name="wrn-10-1", depth=10, widen=self.widen,
                         num_classes=self.num_classes, image_size=16,
                         channels=self.channels, split_group=self.split_group)


CONFIG = WRNConfig()
