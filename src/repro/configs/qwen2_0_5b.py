"""qwen2-0.5b [dense] — GQA with QKV bias.
[arXiv:2407.10671; assignment row: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    source="arXiv:2407.10671",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    long_context_mode="swa",
)
