"""Config registry: ``get_config(arch_id)`` and the assigned-architecture list."""
from __future__ import annotations

import importlib

from repro.configs.base import (FLConfig, INPUT_SHAPES, ModelConfig,
                                ShapeConfig, TrainConfig)

# arch-id -> module name
ARCHS = {
    "gemma3-4b":            "gemma3_4b",
    "internvl2-26b":        "internvl2_26b",
    "qwen3-moe-30b-a3b":    "qwen3_moe_30b_a3b",
    "phi3-medium-14b":      "phi3_medium_14b",
    "llama3.2-1b":          "llama3_2_1b",
    "whisper-medium":       "whisper_medium",
    "qwen2-0.5b":           "qwen2_0_5b",
    "rwkv6-3b":             "rwkv6_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "deepseek-v2-236b":     "deepseek_v2_236b",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch_id]}")
    return mod.CONFIG


def get_wrn_config():
    from repro.configs.wrn_cifar import CONFIG
    return CONFIG


__all__ = ["ARCHS", "get_config", "get_wrn_config", "ModelConfig",
           "ShapeConfig", "INPUT_SHAPES", "FLConfig", "TrainConfig"]
