"""The paper's §3.1: clustered data (metadata) selection.

Pipeline (per client k):
  activation maps A_k^[j]  --flatten-->  (N, D)
  PCA to ``pca_components`` features     (N, P)      [16*32*32 -> 200 in paper]
  K-means per class, ``clusters_per_class`` clusters
  representative = sample closest (Euclidean) to each cluster centre
  D_M_k = activation maps of the representatives

Everything is pure JAX with static shapes (empty classes/clusters handled via
masks), so it jits, vmaps over clients, and lowers inside the distributed
train step. The K-means assignment step optionally routes through the Pallas
kernel (``use_pallas=True``; interpret mode on CPU).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

BIG = 1e30


# --------------------------------------------------------------------------
# PCA
# --------------------------------------------------------------------------
class PCAState(NamedTuple):
    mean: jnp.ndarray          # (D,)
    components: jnp.ndarray    # (P, D) rows = principal axes
    explained: jnp.ndarray     # (P,) eigenvalues


def pca_fit(x: jnp.ndarray, num_components: int,
            mask: Optional[jnp.ndarray] = None) -> PCAState:
    """PCA via the Gram trick when N < D (the paper's regime: a client's few
    thousand maps vs D=16384), else via the covariance matrix. ``mask`` marks
    valid rows; invalid rows get zero weight."""
    n, d = x.shape
    p = num_components
    w = jnp.ones((n,), x.dtype) if mask is None else mask.astype(x.dtype)
    cnt = jnp.maximum(w.sum(), 1.0)
    mean = (x * w[:, None]).sum(0) / cnt
    xc = (x - mean) * w[:, None]
    if n <= d:
        g = (xc @ xc.T) / cnt                       # (N, N) Gram
        evals, evecs = jnp.linalg.eigh(g)           # ascending
        evals, evecs = evals[::-1][:p], evecs[:, ::-1][:, :p]
        safe = jnp.sqrt(jnp.maximum(evals * cnt, 1e-12))
        comps = (xc.T @ evecs) / safe               # (D, P) unit-norm cols
        comps = comps.T
    else:
        cov = (xc.T @ xc) / cnt                     # (D, D)
        evals, evecs = jnp.linalg.eigh(cov)
        evals, evecs = evals[::-1][:p], evecs[:, ::-1][:, :p]
        comps = evecs.T
    return PCAState(mean, comps.astype(x.dtype), evals.astype(x.dtype))


def pca_transform(state: PCAState, x: jnp.ndarray) -> jnp.ndarray:
    return (x - state.mean) @ state.components.T


# --------------------------------------------------------------------------
# K-means (Lloyd, deterministic k-means++-style farthest-point init)
# --------------------------------------------------------------------------
class KMeansState(NamedTuple):
    centroids: jnp.ndarray     # (K, P)
    assignment: jnp.ndarray    # (N,) int32
    distances: jnp.ndarray     # (N,) squared dist to own centroid
    cluster_sizes: jnp.ndarray # (K,)


def _pairwise_sq_dists(x: jnp.ndarray, c: jnp.ndarray,
                       use_pallas: bool = False) -> jnp.ndarray:
    """(N,P)x(K,P) -> (N,K) squared Euclidean distances.
    ||x-c||^2 = ||x||^2 + ||c||^2 - 2 x.c — the MXU-friendly form the Pallas
    kernel implements with centroids resident in VMEM."""
    if use_pallas:
        from repro.kernels.ops import kmeans_pairwise_dist
        return kmeans_pairwise_dist(x, c)
    x2 = jnp.sum(x * x, -1, keepdims=True)
    c2 = jnp.sum(c * c, -1)
    return x2 + c2[None, :] - 2.0 * (x @ c.T)


def kmeans_init(x: jnp.ndarray, k: int, key: jax.Array,
                mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """k-means++-flavoured init: first centre random valid point, then
    farthest-point (deterministic given key, robust for selection use)."""
    n = x.shape[0]
    valid = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
    logits = jnp.where(valid, 0.0, -jnp.inf)
    first = jax.random.categorical(key, logits)
    centroids = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, c):
        d = _pairwise_sq_dists(x, c)                 # (N, K)
        live = jnp.arange(k) < i
        d = jnp.where(live[None, :], d, BIG)
        dmin = jnp.min(d, axis=1)
        dmin = jnp.where(valid, dmin, -BIG)
        far = jnp.argmax(dmin)
        return c.at[i].set(x[far])

    return jax.lax.fori_loop(1, k, body, centroids)


@functools.partial(jax.jit, static_argnames=("k", "iters", "use_pallas"))
def kmeans(x: jnp.ndarray, k: int, key: jax.Array, iters: int = 25,
           mask: Optional[jnp.ndarray] = None,
           use_pallas: bool = False) -> KMeansState:
    n = x.shape[0]
    valid = (jnp.ones((n,), bool) if mask is None else mask.astype(bool))
    c0 = kmeans_init(x, k, key, mask)

    def step(_, c):
        d = _pairwise_sq_dists(x, c, use_pallas)
        d = jnp.where(valid[:, None], d, BIG)
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=x.dtype) * valid[:, None]
        counts = onehot.sum(0)                        # (K,)
        sums = onehot.T @ x                           # (K, P)
        newc = sums / jnp.maximum(counts, 1.0)[:, None]
        # keep empty clusters where they were (classic Lloyd behaviour)
        return jnp.where(counts[:, None] > 0, newc, c)

    c = jax.lax.fori_loop(0, iters, step, c0)
    d = _pairwise_sq_dists(x, c, use_pallas)
    d = jnp.where(valid[:, None], d, BIG)
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    own = jnp.take_along_axis(d, assign[:, None], axis=1)[:, 0]
    sizes = (jax.nn.one_hot(assign, k) * valid[:, None]).sum(0)
    return KMeansState(c, assign, own, sizes)


def representatives(x: jnp.ndarray, km: KMeansState,
                    mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Paper: 'within each cluster choose the sample closest in Euclidean
    distance to the cluster centre'. Returns (K,) indices into x rows
    (empty cluster -> index of globally nearest valid point, masked later)."""
    n, k = x.shape[0], km.centroids.shape[0]
    valid = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
    d = _pairwise_sq_dists(x, km.centroids)           # (N, K)
    same = km.assignment[:, None] == jnp.arange(k)[None, :]
    d = jnp.where(same & valid[:, None], d, BIG)
    return jnp.argmin(d, axis=0).astype(jnp.int32)


# --------------------------------------------------------------------------
# Full §3.1 pipeline
# --------------------------------------------------------------------------
class Selection(NamedTuple):
    indices: jnp.ndarray       # (num_classes*K,) indices into the client's data
    valid: jnp.ndarray         # (num_classes*K,) bool — cluster non-empty
    features: jnp.ndarray      # (N, P) the PCA features (for diagnostics)


@functools.partial(jax.jit,
                   static_argnames=("num_classes", "clusters_per_class",
                                    "pca_components", "kmeans_iters",
                                    "use_pallas", "per_class"))
def select_metadata(acts: jnp.ndarray, labels: Optional[jnp.ndarray],
                    key: jax.Array, *, num_classes: int = 10,
                    clusters_per_class: int = 10, pca_components: int = 200,
                    kmeans_iters: int = 25, use_pallas: bool = False,
                    per_class: bool = True) -> Selection:
    """acts: (N, ...) activation maps at split layer j (flattened internally).
    labels: (N,) int — paper clusters per class; ``per_class=False`` clusters
    all samples together (the LM generalization, no labels needed)."""
    n = acts.shape[0]
    flat = acts.reshape(n, -1).astype(jnp.float32)
    p = min(pca_components, n - 1 if n > 1 else 1, flat.shape[1])
    pca = pca_fit(flat, p)
    feats = pca_transform(pca, flat)

    if not per_class or labels is None:
        km = kmeans(feats, clusters_per_class, key, kmeans_iters,
                    use_pallas=use_pallas)
        idx = representatives(feats, km)
        valid = km.cluster_sizes[jnp.arange(clusters_per_class)] > 0
        return Selection(idx, valid, feats)

    keys = jax.random.split(key, num_classes)

    def one_class(c, k_c):
        m = labels == c
        km = kmeans(feats, clusters_per_class, k_c, kmeans_iters,
                    mask=m, use_pallas=use_pallas)
        idx = representatives(feats, km, mask=m)
        return idx, km.cluster_sizes > 0

    idxs, valids = jax.vmap(one_class)(jnp.arange(num_classes), keys)
    return Selection(idxs.reshape(-1), valids.reshape(-1), feats)


def selected_fraction(sel: Selection, n_total: int) -> jnp.ndarray:
    """The paper's headline metric: |D_M_k| / |D_k| (~0.8% in the paper)."""
    return sel.valid.sum() / n_total
