"""The paper's §3.1: clustered data (metadata) selection.

Pipeline (per client k):
  activation maps A_k^[j]  --flatten-->  (N, D)
  PCA to ``pca_components`` features     (N, P)      [16*32*32 -> 200 in paper]
  K-means per class, ``clusters_per_class`` clusters
  representative = sample closest (Euclidean) to each cluster centre
  D_M_k = activation maps of the representatives

Everything is pure JAX with static shapes (empty classes/clusters handled via
masks), so it jits, vmaps over clients, and lowers inside the distributed
train step.

This is the system's hot path (every round, every client), so the engine is
built around one primitive: the **fused Lloyd step** — biased distances,
argmin assignment, and masked centroid sum/count accumulation in a single
pass over the data (``repro.kernels`` has the Pallas TPU kernel; the jnp
oracle in ``kernels/ref.py`` is the CPU path). Per-class clustering is a
single label-masked problem over ``num_classes * clusters_per_class``
cluster slots — one distance evaluation per sweep instead of one per class —
and Lloyd sweeps exit early once the centroids reach their fixed point
(bit-identical result to running all ``kmeans_iters`` sweeps, since a
converged sweep is a no-op). The final sweep's (assign, mindist, sums,
counts) ride the while_loop carry, so the old post-loop recompute sweep
only runs (under ``lax.cond``) when the loop dies at the iteration cap
without converging. ``select_metadata_batched`` vmaps the whole pipeline
across a stacked cohort of clients.

``select_metadata_reference`` keeps the seed implementation (per-class
``vmap`` of independent K-means runs, full distance matrices re-read through
``one_hot`` matmuls) as the identity/benchmark oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.obs.profile import profiled_jit

# the additive forbidden-column mask constant — shared with the kernel's
# oracle (ops.py pads with it too); the f32 absorption argument in
# kernels/kmeans.py depends on producer and consumer agreeing on it
BIG = kref.BIG


# --------------------------------------------------------------------------
# PCA
# --------------------------------------------------------------------------
class PCAState(NamedTuple):
    mean: jnp.ndarray          # (D,)
    components: jnp.ndarray    # (P, D) rows = principal axes
    explained: jnp.ndarray     # (P,) eigenvalues


def _pca_exact(xc: jnp.ndarray, cnt: jnp.ndarray, p: int):
    """Exact top-p eigenpairs: Gram trick when N <= D, else covariance."""
    n, d = xc.shape
    if n <= d:
        g = (xc @ xc.T) / cnt                       # (N, N) Gram
        evals, evecs = jnp.linalg.eigh(g)           # ascending
        evals, evecs = evals[::-1][:p], evecs[:, ::-1][:, :p]
        safe = jnp.sqrt(jnp.maximum(evals * cnt, 1e-12))
        comps = (xc.T @ evecs) / safe               # (D, P) unit-norm cols
        comps = comps.T
    else:
        cov = (xc.T @ xc) / cnt                     # (D, D)
        evals, evecs = jnp.linalg.eigh(cov)
        evals, evecs = evals[::-1][:p], evecs[:, ::-1][:, :p]
        comps = evecs.T
    return evals, comps


def _pca_randomized(xc: jnp.ndarray, cnt: jnp.ndarray, p: int,
                    key: jax.Array, oversample: int, power_iters: int):
    """Randomized range finder (Halko et al.) for the top-p subspace of the
    covariance — O(N*D*(p+oversample)) matmuls instead of the O(D^3) eigh
    that dominates the selection pipeline on wide activation maps. Exact on
    any spectrum that decays within p+oversample directions (real activation
    maps do; that is why the paper's PCA works at all). The Rayleigh-quotient
    small matrix ``Q^T C Q`` applies the covariance once more for free, so
    one power iteration with a wide sketch already nails the subspace.
    Orthonormalization must stay QR — a Cholesky-QR squares the sketch's
    condition number and loses the tail directions in f32. Also returns the
    sketch projection ``b = xc @ q`` and the small-basis eigenvectors so a
    caller can form the features as ``b @ evecs`` without re-reading x."""
    n, d = xc.shape
    l = min(p + oversample, n, d)
    q = jax.random.normal(key, (d, l), xc.dtype)
    q = xc.T @ (xc @ q) / cnt

    def body(_, q):
        q, _ = jnp.linalg.qr(q)
        return xc.T @ (xc @ q) / cnt

    q = jax.lax.fori_loop(0, power_iters, body, q)
    q, _ = jnp.linalg.qr(q)                          # (D, l) orthonormal
    b = xc @ q                                       # (N, l)
    small = (b.T @ b) / cnt                          # (l, l) = Q^T C Q
    evals, evecs = jnp.linalg.eigh(small)
    evals, evecs = evals[::-1][:p], evecs[:, ::-1][:, :p]
    comps = (q @ evecs).T                            # (P, D)
    return evals, comps, b, evecs


def pca_fit(x: jnp.ndarray, num_components: int,
            mask: Optional[jnp.ndarray] = None, *,
            solver: str = "exact", key: Optional[jax.Array] = None,
            oversample: int = 32, power_iters: int = 1) -> PCAState:
    """PCA via the Gram trick when N < D (the paper's regime: a client's few
    thousand maps vs D=16384), else via the covariance matrix. ``mask`` marks
    valid rows; invalid rows get zero weight.

    ``solver='exact'`` (default) reproduces the seed numerics exactly.
    ``solver='randomized'`` swaps the D x D eigh for a randomized range
    finder — same subspace on fast-decaying spectra, and K-means selections
    are invariant to the basis rotation within that subspace. ``key`` seeds
    the random test matrix (a fixed default keeps it deterministic)."""
    n, d = x.shape
    p = num_components
    w = jnp.ones((n,), x.dtype) if mask is None else mask.astype(x.dtype)
    cnt = jnp.maximum(w.sum(), 1.0)
    mean = (x * w[:, None]).sum(0) / cnt
    xc = (x - mean) * w[:, None]
    if solver == "exact":
        evals, comps = _pca_exact(xc, cnt, p)
    elif solver == "randomized":
        if key is None:
            key = jax.random.PRNGKey(0x9CA)
        evals, comps, _, _ = _pca_randomized(xc, cnt, p, key, oversample,
                                             power_iters)
    else:
        raise ValueError(f"unknown PCA solver: {solver!r}")
    return PCAState(mean, comps.astype(x.dtype), evals.astype(x.dtype))


def pca_fit_transform(x: jnp.ndarray, num_components: int, *,
                      solver: str = "exact", key: Optional[jax.Array] = None,
                      oversample: int = 32, power_iters: int = 1):
    """Fit + project in one go -> (PCAState, features). For the randomized
    solver the features come straight from the sketch (``b @ evecs``), saving
    one full (N, D) read versus fit-then-transform."""
    if solver != "randomized":
        state = pca_fit(x, num_components, solver=solver, key=key)
        return state, pca_transform(state, x)
    n, d = x.shape
    mean = x.mean(0)
    xc = x - mean
    cnt = jnp.asarray(float(n), x.dtype)
    if key is None:
        key = jax.random.PRNGKey(0x9CA)
    evals, comps, b, evecs = _pca_randomized(xc, cnt, num_components, key,
                                             oversample, power_iters)
    state = PCAState(mean, comps.astype(x.dtype), evals.astype(x.dtype))
    return state, b @ evecs


def pca_transform(state: PCAState, x: jnp.ndarray) -> jnp.ndarray:
    return (x - state.mean) @ state.components.T


# --------------------------------------------------------------------------
# K-means (Lloyd, deterministic k-means++-style farthest-point init)
# --------------------------------------------------------------------------
class KMeansState(NamedTuple):
    centroids: jnp.ndarray     # (K, P)
    assignment: jnp.ndarray    # (N,) int32
    distances: jnp.ndarray     # (N,) squared dist to own centroid
    cluster_sizes: jnp.ndarray # (K,)
    iters: Optional[jnp.ndarray] = None  # () int32 Lloyd sweeps executed
    #   (early convergence exit < cap); None on paths that don't count


def _pairwise_sq_dists(x: jnp.ndarray, c: jnp.ndarray,
                       use_pallas: bool = False) -> jnp.ndarray:
    """(N,P)x(K,P) -> (N,K) squared Euclidean distances.
    ||x-c||^2 = ||x||^2 + ||c||^2 - 2 x.c — the MXU-friendly form the Pallas
    kernel implements with centroids resident in VMEM."""
    if use_pallas:
        from repro.kernels.ops import kmeans_pairwise_dist
        return kmeans_pairwise_dist(x, c)
    return kref.kmeans_pairwise_dist_ref(x, c)


def _lloyd_step(x: jnp.ndarray, c: jnp.ndarray, lmask: jnp.ndarray,
                use_pallas: bool = False):
    """One fused Lloyd sweep -> (assign, mindist, sums, counts)."""
    if use_pallas:
        from repro.kernels.ops import kmeans_lloyd_step
        return kmeans_lloyd_step(x, c, lmask)
    return kref.kmeans_lloyd_ref(x, c, lmask)


def _lloyd_iterate(x: jnp.ndarray, c0: jnp.ndarray, lmask: jnp.ndarray,
                   iters: int, use_pallas: bool):
    """Run Lloyd sweeps until the centroids reach their fixed point (or the
    ``iters`` cap). Early exit is bit-identical to running all sweeps: once
    ``new_c == c``, every later sweep recomputes exactly the same state.

    Returns (centroids, (assign, mindist, sums, counts), sweeps) — the
    final sweep's statistics ride through the while_loop carry, so callers
    get them WITHOUT a separate post-loop ``_lloyd_step``, and ``sweeps``
    is the () int32 count of Lloyd iterations actually executed (the
    early-exit telemetry the obs trace reports per client). On a convergence
    exit the carried stats were computed at centroids equal to the returned
    ones (``newc == c``), so they ARE the final stats; only a cap exit
    (non-converged after ``iters`` sweeps, whose carried stats belong to
    the penultimate centroids) pays a ``lax.cond`` recompute — bit-identical
    to the old always-recompute by construction."""

    def sweep(c):
        assign, mind, sums, counts = _lloyd_step(x, c, lmask, use_pallas)
        newc = sums / jnp.maximum(counts, 1.0)[:, None]
        # keep empty clusters where they were (classic Lloyd behaviour);
        # the cast keeps the carry dtype-stable when x is not f32 (sums is
        # always f32 via preferred_element_type) — a no-op for f32
        newc = jnp.where(counts[:, None] > 0, newc, c).astype(c.dtype)
        return newc, (assign, mind, sums, counts)

    def cond(state):
        i, _, _, done = state
        return (i < iters) & jnp.logical_not(done)

    def body(state):
        i, c, _, _ = state
        newc, stats = sweep(c)
        return i + 1, newc, stats, jnp.all(newc == c)

    n, k = x.shape[0], c0.shape[0]
    # carry dtypes must match _lloyd_step's: mindist and counts come back
    # in x.dtype (sums is f32 via preferred_element_type)
    stats0 = (jnp.zeros((n,), jnp.int32), jnp.zeros((n,), x.dtype),
              jnp.zeros((k, x.shape[1]), jnp.float32),
              jnp.zeros((k,), x.dtype))
    i, c, stats, done = jax.lax.while_loop(
        cond, body, (0, c0, stats0, jnp.asarray(False)))
    # cap exit (or iters == 0, where the loop never ran): the carried stats
    # lag the returned centroids by one sweep — recompute at c
    stats = jax.lax.cond(done, lambda: stats,
                         lambda: _lloyd_step(x, c, lmask, use_pallas))
    return c, stats, jnp.asarray(i, jnp.int32)


def kmeans_init(x: jnp.ndarray, k: int, key: jax.Array,
                mask: Optional[jnp.ndarray] = None,
                use_pallas: bool = False) -> jnp.ndarray:
    """k-means++-flavoured init: first centre random valid point, then
    farthest-point (deterministic given key, robust for selection use).

    The jnp path keeps a running min-distance-to-chosen-centres vector and
    evaluates one new centre per step (K x fewer FLOPs, same min in exact
    arithmetic). The Pallas path evaluates the full (N, K) tile per step via
    the VMEM-resident distance kernel — on the MXU the tile is effectively
    free and the incremental matvec would be VPU-bound."""
    n = x.shape[0]
    valid = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
    logits = jnp.where(valid, 0.0, -jnp.inf)
    first = jax.random.categorical(key, logits)
    centroids = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    if use_pallas:
        def body(i, c):
            d = _pairwise_sq_dists(x, c, use_pallas)     # (N, K)
            live = jnp.arange(k) < i
            d = jnp.where(live[None, :], d, BIG)
            dmin = jnp.min(d, axis=1)
            dmin = jnp.where(valid, dmin, -BIG)
            far = jnp.argmax(dmin)
            return c.at[i].set(x[far])

        return jax.lax.fori_loop(1, k, body, centroids)

    x2 = jnp.sum(x * x, axis=1)

    def dist_to(c_row):
        return x2 + jnp.sum(c_row * c_row) - 2.0 * (x @ c_row)

    def body(i, state):
        c, dmin = state
        far = jnp.argmax(jnp.where(valid, dmin, -BIG))
        c = c.at[i].set(x[far])
        return c, jnp.minimum(dmin, dist_to(x[far]))

    c, _ = jax.lax.fori_loop(1, k, body, (centroids, dist_to(x[first])))
    return c


@profiled_jit(static_argnames=("k", "iters", "use_pallas"))
def kmeans(x: jnp.ndarray, k: int, key: jax.Array, iters: int = 25,
           mask: Optional[jnp.ndarray] = None,
           use_pallas: bool = False) -> KMeansState:
    n = x.shape[0]
    valid = (jnp.ones((n,), bool) if mask is None else mask.astype(bool))
    lmask = jnp.where(valid, 0.0, BIG)[:, None] * jnp.ones((1, k), x.dtype)
    c0 = kmeans_init(x, k, key, mask, use_pallas=use_pallas)
    c, (assign, own, _, sizes), it = _lloyd_iterate(x, c0, lmask, iters,
                                                    use_pallas)
    return KMeansState(c, assign, own, sizes, it)


def representatives(x: jnp.ndarray, km: KMeansState,
                    mask: Optional[jnp.ndarray] = None,
                    use_pallas: bool = False) -> jnp.ndarray:
    """Paper: 'within each cluster choose the sample closest in Euclidean
    distance to the cluster centre'. Returns (K,) indices into x rows.

    An EMPTY cluster (``km.cluster_sizes[j] == 0``) yields the index of the
    valid point globally nearest to that cluster's centre, so every returned
    index is a sensible row of x; consumers still mask empty slots via
    ``cluster_sizes > 0``. (It used to be row 0 — the argmin of an all-BIG
    column.) With no valid rows at all every index degenerates to 0."""
    n, k = x.shape[0], km.centroids.shape[0]
    valid = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
    d = _pairwise_sq_dists(x, km.centroids, use_pallas)   # (N, K)
    dvalid = jnp.where(valid[:, None], d, BIG)
    same = km.assignment[:, None] == jnp.arange(k)[None, :]
    dsame = jnp.where(same, dvalid, BIG)
    empty = km.cluster_sizes <= 0
    idx = jnp.where(empty, jnp.argmin(dvalid, axis=0),
                    jnp.argmin(dsame, axis=0))
    return idx.astype(jnp.int32)


# --------------------------------------------------------------------------
# Full §3.1 pipeline
# --------------------------------------------------------------------------
class Selection(NamedTuple):
    indices: jnp.ndarray       # (num_classes*K,) indices into the client's data
    valid: jnp.ndarray         # (num_classes*K,) bool — cluster non-empty
    features: jnp.ndarray      # (N, P) the PCA features (for diagnostics)
    lloyd_iters: Optional[jnp.ndarray] = None  # () int32 Lloyd sweeps run
    #   (always populated by select_metadata*; defaulted so 3-positional
    #   constructions keep working)


def _fit_features(acts: jnp.ndarray, pca_components: int, pca_solver: str):
    n = acts.shape[0]
    flat = acts.reshape(n, -1).astype(jnp.float32)
    p = min(pca_components, n - 1 if n > 1 else 1, flat.shape[1])
    _, feats = pca_fit_transform(flat, p, solver=pca_solver)
    return feats


@profiled_jit(static_argnames=("num_classes", "clusters_per_class",
                               "pca_components", "kmeans_iters",
                               "use_pallas", "per_class", "pca_solver"))
def select_metadata(acts: jnp.ndarray, labels: Optional[jnp.ndarray],
                    key: jax.Array, *, num_classes: int = 10,
                    clusters_per_class: int = 10, pca_components: int = 200,
                    kmeans_iters: int = 25, use_pallas: bool = False,
                    per_class: bool = True,
                    pca_solver: str = "exact") -> Selection:
    """acts: (N, ...) activation maps at split layer j (flattened internally).
    labels: (N,) int — paper clusters per class; ``per_class=False`` clusters
    all samples together (the LM generalization, no labels needed).

    Per-class clustering is one label-masked problem over
    ``num_classes * clusters_per_class`` cluster slots: a single fused Lloyd
    sweep per iteration assigns every sample among its own class's slots
    (additive BIG mask on foreign columns) and accumulates all centroid
    statistics — versus the seed path's per-class vmap that re-scanned all N
    rows once per class. The final sweep's per-row own-centroid distances
    also drive representative extraction, so no extra distance matrix is
    evaluated. ``pca_solver='randomized'`` swaps the exact eigh for the
    randomized range finder (same selections on decaying spectra)."""
    n = acts.shape[0]
    feats = _fit_features(acts, pca_components, pca_solver)

    if not per_class or labels is None:
        km = kmeans(feats, clusters_per_class, key, kmeans_iters,
                    use_pallas=use_pallas)
        idx = representatives(feats, km, use_pallas=use_pallas)
        valid = km.cluster_sizes[jnp.arange(clusters_per_class)] > 0
        return Selection(idx, valid, feats, km.iters)

    kk = clusters_per_class
    ck = num_classes * kk
    keys = jax.random.split(key, num_classes)

    # per-class farthest-point init (same keys/structure as the seed path)
    def init_one(c, k_c):
        return kmeans_init(feats, kk, k_c, mask=labels == c,
                           use_pallas=use_pallas)

    c0 = jax.vmap(init_one)(jnp.arange(num_classes), keys)   # (C, K, P)
    c0 = c0.reshape(ck, feats.shape[1])

    # single-pass label mask: row i may only join its own class's slots
    slot_class = jnp.arange(ck) // kk
    lmask = jnp.where(labels[:, None] == slot_class[None, :], 0.0,
                      BIG).astype(feats.dtype)

    c, (assign, own, _, sizes), lloyd_it = _lloyd_iterate(
        feats, c0, lmask, kmeans_iters, use_pallas)

    # representatives from the same sweep: per-slot argmin of own distance
    same = assign[:, None] == jnp.arange(ck)[None, :]
    w = jnp.min(lmask, axis=1) <= 0.0                        # row admissible
    drep = jnp.where(same & w[:, None], own[:, None], BIG)
    idx = jnp.argmin(drep, axis=0).astype(jnp.int32)

    # empty-slot contract (matches ``representatives``): the admissible row
    # nearest the slot's centre. Computed unconditionally — on the jnp path
    # the pairwise matrix is the same expression the last Lloyd sweep just
    # evaluated, so XLA CSEs it to ~zero cost (a lax.cond would block
    # that, and under vmap both branches run anyway); the Pallas path pays
    # one extra distance pass on top of the carried-sweep count.
    dfull = jnp.where(lmask <= 0.0,
                      _pairwise_sq_dists(feats, c, use_pallas), BIG)
    empty = sizes <= 0
    idx = jnp.where(empty, jnp.argmin(dfull, axis=0).astype(jnp.int32), idx)
    return Selection(idx, sizes > 0, feats, lloyd_it)


@profiled_jit(static_argnames=("num_classes", "clusters_per_class",
                               "pca_components", "kmeans_iters",
                               "use_pallas", "per_class", "pca_solver"))
def select_metadata_batched(acts: jnp.ndarray, labels: Optional[jnp.ndarray],
                            keys: jax.Array, *, num_classes: int = 10,
                            clusters_per_class: int = 10,
                            pca_components: int = 200,
                            kmeans_iters: int = 25, use_pallas: bool = False,
                            per_class: bool = True,
                            pca_solver: str = "exact") -> Selection:
    """vmap of ``select_metadata`` over a stacked cohort of clients.

    acts: (B, N, ...), labels: (B, N) or None, keys: (B,) client keys (e.g.
    ``jax.random.split(key, B)``). Returns a Selection whose fields carry a
    leading client axis. Keyword args are the static ``select_metadata``
    knobs (same defaults) and apply to every client."""
    fn = functools.partial(
        select_metadata, num_classes=num_classes,
        clusters_per_class=clusters_per_class, pca_components=pca_components,
        kmeans_iters=kmeans_iters, use_pallas=use_pallas, per_class=per_class,
        pca_solver=pca_solver)
    if labels is None:
        return jax.vmap(lambda a, k: fn(a, None, k))(acts, keys)
    return jax.vmap(fn)(acts, labels, keys)


@profiled_jit(static_argnames=("num_classes", "clusters_per_class",
                               "pca_components", "kmeans_iters",
                               "use_pallas", "per_class"))
def select_metadata_reference(acts: jnp.ndarray,
                              labels: Optional[jnp.ndarray],
                              key: jax.Array, *, num_classes: int = 10,
                              clusters_per_class: int = 10,
                              pca_components: int = 200,
                              kmeans_iters: int = 25,
                              use_pallas: bool = False,
                              per_class: bool = True) -> Selection:
    """The seed implementation, kept verbatim as the identity oracle and
    benchmark baseline: independent per-class K-means runs under ``vmap``,
    each running all ``kmeans_iters`` sweeps over the full distance matrix
    and re-reading it through a ``one_hot`` matmul, plus a separate distance
    evaluation for representative extraction."""
    n = acts.shape[0]
    flat = acts.reshape(n, -1).astype(jnp.float32)
    p = min(pca_components, n - 1 if n > 1 else 1, flat.shape[1])
    pca = pca_fit(flat, p)
    feats = pca_transform(pca, flat)

    def seed_kmeans_init(x, k, key, mask=None):
        nn = x.shape[0]
        valid = jnp.ones((nn,), bool) if mask is None else mask.astype(bool)
        logits = jnp.where(valid, 0.0, -jnp.inf)
        first = jax.random.categorical(key, logits)
        centroids = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

        def body(i, c):
            d = _pairwise_sq_dists(x, c, use_pallas)    # full (N, K) per step
            live = jnp.arange(k) < i
            d = jnp.where(live[None, :], d, BIG)
            dmin = jnp.min(d, axis=1)
            dmin = jnp.where(valid, dmin, -BIG)
            far = jnp.argmax(dmin)
            return c.at[i].set(x[far])

        return jax.lax.fori_loop(1, k, body, centroids)

    def seed_kmeans(x, k, key, iters, mask=None):
        nn = x.shape[0]
        valid = (jnp.ones((nn,), bool) if mask is None else mask.astype(bool))
        c0 = seed_kmeans_init(x, k, key, mask)

        def step(_, c):
            d = _pairwise_sq_dists(x, c, use_pallas)
            d = jnp.where(valid[:, None], d, BIG)
            assign = jnp.argmin(d, axis=1)
            onehot = jax.nn.one_hot(assign, k, dtype=x.dtype) * valid[:, None]
            counts = onehot.sum(0)
            sums = onehot.T @ x
            newc = sums / jnp.maximum(counts, 1.0)[:, None]
            return jnp.where(counts[:, None] > 0, newc, c)

        c = jax.lax.fori_loop(0, iters, step, c0)
        d = _pairwise_sq_dists(x, c, use_pallas)
        d = jnp.where(valid[:, None], d, BIG)
        assign = jnp.argmin(d, axis=1).astype(jnp.int32)
        own = jnp.take_along_axis(d, assign[:, None], axis=1)[:, 0]
        sizes = (jax.nn.one_hot(assign, k) * valid[:, None]).sum(0)
        return KMeansState(c, assign, own, sizes)

    # the seed loop always runs all sweeps — populate lloyd_iters anyway so
    # reference and fused Selections have the same pytree structure
    ran = jnp.asarray(kmeans_iters, jnp.int32)
    if not per_class or labels is None:
        km = seed_kmeans(feats, clusters_per_class, key, kmeans_iters)
        idx = representatives(feats, km)
        valid = km.cluster_sizes[jnp.arange(clusters_per_class)] > 0
        return Selection(idx, valid, feats, ran)

    keys = jax.random.split(key, num_classes)

    def one_class(c, k_c):
        m = labels == c
        km = seed_kmeans(feats, clusters_per_class, k_c, kmeans_iters, mask=m)
        idx = representatives(feats, km, mask=m)
        return idx, km.cluster_sizes > 0

    idxs, valids = jax.vmap(one_class)(jnp.arange(num_classes), keys)
    return Selection(idxs.reshape(-1), valids.reshape(-1), feats, ran)


def selected_fraction(sel: Selection, n_total: int) -> jnp.ndarray:
    """The paper's headline metric: |D_M_k| / |D_k| (~0.8% in the paper)."""
    return sel.valid.sum() / n_total
