"""§3.3 ModelCompose + evaluation of M_COM(t)."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.split import SplitModel

PyTree = Any


def compose(model: SplitModel, fedavg_params: PyTree,
            upper_trained: PyTree) -> PyTree:
    """M_COM(t) = [ W_G^l(t-1) ; W_S^u(t) ]."""
    lower, _ = model.split(fedavg_params)
    return model.merge(lower, upper_trained)


@functools.partial(jax.jit, static_argnames=("model", "batch_size"))
def _eval_batched(model: SplitModel, params: PyTree, x, y, batch_size: int):
    n = x.shape[0]
    steps = n // batch_size
    xs = x[:steps * batch_size].reshape((steps, batch_size) + x.shape[1:])
    ys = y[:steps * batch_size].reshape(steps, batch_size)

    def body(correct, batch):
        bx, by = batch
        logits = model.apply(params, bx)
        if logits.ndim == 3:                 # LM: next-token accuracy
            pred = jnp.argmax(logits[:, :-1], -1)
            hits = (pred == bx[:, 1:]).mean(-1).sum()
        else:
            hits = (jnp.argmax(logits, -1) == by).sum()
        return correct + hits, None

    correct, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ys))
    return correct / (steps * batch_size)


def evaluate(model: SplitModel, params: PyTree, x, y,
             batch_size: int = 200) -> float:
    """Test accuracy of a (composed) model — the paper's reported metric."""
    return float(_eval_batched(model, params, jnp.asarray(x), jnp.asarray(y),
                               min(batch_size, x.shape[0])))
