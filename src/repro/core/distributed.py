"""Pod-scale round engine — the ``repro.core.distributed`` runtime that
``rounds.py``/``fl/client.py`` promise.

The simulator (``repro.core.rounds``) runs Algorithm 1 at per-client
granularity; this module runs the same math with the whole cohort STACKED:

  select_cohort        Extract&Selection (§3.1) over a stacked cohort,
                       (a) sharded over the mesh's ``data`` axis with
                       ``shard_map`` — a pod of devices selects for
                       device-count x the clients per call — and
                       (b) streamed in client CHUNKS with each chunk's
                       activations gathered down to the selected metadata
                       before the next chunk runs (``gather=True``), so a
                       mega-cohort's activation memory is one chunk's, not
                       the cohort's (the old ``MAX_BATCHED_ELEMENTS``
                       fall-back-to-sequential cliff is gone; the input
                       stack itself — the clients' raw data — is the
                       irreducible footprint of the stacked engine).
  local_update_cohort  LocalUpdate (§3.2) as ONE compiled ``local_update``
                       over the stacked cohort (lax.map over the client
                       axis, shard_map across devices) — the last
                       per-client Python loop in the round is gone.
  cohort_round         both of the above plus the ledger accounting, i.e.
                       the whole client side of a round.
  run_round_distributed  Algorithm 1 end to end on the stacked cohort.

Every client's selection and local update are independent, so chunking and
sharding are pure schedules: results are bit-identical to the sequential
per-client loop (asserted by tests/test_distributed.py and
tests/test_core_fl.py).
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.configs.base import FLConfig
from repro.core import fedavg as fa
from repro.core.selection import (Selection, select_metadata,
                                  select_metadata_batched)
from repro.core.split import SplitModel
from repro.data.partition import ClientData
from repro.fl.comms import CommLedger
from repro.obs.profile import profiled_jit

PyTree = Any


# --------------------------------------------------------------------------
# cohort stacking
# --------------------------------------------------------------------------
def cohort_is_stackable(clients: List[ClientData]) -> bool:
    """A cohort stacks when every client's data shapes agree (the ragged
    case stays on the sequential per-client path)."""
    return len({(c.data.x.shape, c.data.y.shape) for c in clients}) == 1


def cohort_arrays(clients: List[ClientData]
                  ) -> Optional[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Stack the cohort's data -> (xs (B, N, ...), ys (B, N)), or None when
    the cohort is ragged."""
    if not cohort_is_stackable(clients):
        return None
    xs = jnp.stack([jnp.asarray(c.data.x) for c in clients])
    ys = jnp.stack([jnp.asarray(c.data.y) for c in clients])
    return xs, ys


def selection_mesh(num_devices: int = 0) -> Mesh:
    """A 1-D ``data`` mesh over the host's devices for sharded selection
    (the production pod meshes live in ``launch/mesh.py``; selection only
    needs the client axis)."""
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def data_axis_size(mesh: Optional[Mesh]) -> int:
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)


def _pad_clients(arrays, ndev: int):
    """Pad every array's leading client axis to a multiple of ``ndev`` with
    copies of client 0 (their outputs are discarded — selections/updates are
    client-independent). Returns (padded arrays, unpad fn)."""
    b = arrays[0].shape[0]
    pad = (-b) % ndev
    if not pad:
        return arrays, lambda tree: tree
    padded = tuple(
        None if a is None else
        jnp.concatenate([a, jnp.repeat(a[:1], pad, axis=0)], axis=0)
        for a in arrays)
    return padded, lambda tree: jax.tree.map(lambda a: a[:b], tree)


def cohort_inputs_fit(clients: List[ClientData]) -> bool:
    """Whether the cohort's RAW INPUT stack fits the stacked engine's
    memory budget. Chunking bounds the per-chunk activation footprint, but
    the input stack itself is the engine's irreducible footprint — past
    this the sequential per-client loop (one client's data at a time) is
    the escape hatch, exactly as before chunking existed. The budget is
    deliberately NOT scaled by the mesh width: ``cohort_arrays`` commits
    the stack to the default device before shard_map reshards it, so one
    device must hold it (sharded-at-stack-time device_put is a ROADMAP
    item)."""
    from repro.core.rounds import MAX_BATCHED_ELEMENTS
    elements = len(clients) * int(np.prod(clients[0].data.x.shape))
    return elements <= MAX_BATCHED_ELEMENTS


def auto_chunk_size(model: SplitModel, params: PyTree, x_shape, x_dtype,
                    num_clients: int, data_axis: int = 1) -> int:
    """Streaming chunk size for a cohort: 0 (one stack) while the stacked
    inputs + activations fit ``rounds.MAX_BATCHED_ELEMENTS``, else the
    largest client count whose stack does. ``data_axis`` scales the budget
    for a sharded chunk (each device holds chunk/data_axis clients)."""
    from repro.core.rounds import MAX_BATCHED_ELEMENTS
    act_shape = jax.eval_shape(
        lambda x: model.apply_lower(params, x),
        jax.ShapeDtypeStruct(x_shape, x_dtype)).shape
    per_client = int(np.prod(x_shape)) + int(np.prod(act_shape))
    budget = MAX_BATCHED_ELEMENTS * max(data_axis, 1)
    if num_clients * per_client <= budget:
        return 0
    return max(1, budget // per_client)


# --------------------------------------------------------------------------
# Extract & Selection over a stacked cohort (§3.1)
# --------------------------------------------------------------------------
def _select_stack(model: SplitModel, params: PyTree, xs: jnp.ndarray,
                  ys: jnp.ndarray, sel_keys: jax.Array, cfg: FLConfig,
                  num_classes: int):
    """The vmapped lower forward + §3.1 pipeline on one stacked chunk."""
    acts = jax.vmap(lambda x: model.apply_lower(params, x))(xs)
    sels = select_metadata_batched(
        acts, ys, sel_keys, num_classes=num_classes,
        clusters_per_class=cfg.clusters_per_class,
        pca_components=cfg.pca_components, kmeans_iters=cfg.kmeans_iters,
        use_pallas=cfg.use_pallas_selection, pca_solver=cfg.pca_solver)
    return acts, sels


def _select_stack_sharded(model: SplitModel, params: PyTree, xs: jnp.ndarray,
                          ys: jnp.ndarray, sel_keys: jax.Array, cfg: FLConfig,
                          num_classes: int, mesh: Mesh):
    """shard_map over the mesh's ``data`` axis: each device runs the §3.1
    pipeline on its local slice of the client axis (no collectives —
    selections are client-independent). Within a shard the clients are
    ``lax.map``-ed, not vmapped: re-batching the pipeline inside the SPMD
    module re-fuses the PCA matmuls and perturbs the eigh just enough to
    flip near-degenerate selections (~1e-4 feature drift), while the
    lax.map body compiles to the same per-client HLO as the sequential
    simulator — bit-identical selections. Cross-client parallelism is the
    device axis itself (size the cohort ~ the axis for full utilization).
    The cohort is padded with copies of client 0 up to a multiple of the
    axis size; padded outputs are sliced away."""
    ndev = data_axis_size(mesh)
    (xs, ys, sel_keys), unpad = _pad_clients((xs, ys, sel_keys), ndev)

    def shard_fn(p, x, y, k):
        # forward under vmap (bit-stable for the conv/matmul forward, as
        # the batched simulator path established) ...
        acts = jax.vmap(lambda xx: model.apply_lower(p, xx))(x)

        def one(args):
            a, yy, kk = args
            return select_metadata(
                a, yy, kk, num_classes=num_classes,
                clusters_per_class=cfg.clusters_per_class,
                pca_components=cfg.pca_components,
                kmeans_iters=cfg.kmeans_iters,
                use_pallas=cfg.use_pallas_selection,
                pca_solver=cfg.pca_solver)

        # ... selection under lax.map (bit-stable for the PCA eigh)
        return acts, jax.lax.map(one, (acts, y, k))

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(), P("data"), P("data"), P("data")),
                   out_specs=P("data"), check_rep=False)
    return unpad(fn(params, xs, ys, sel_keys))


def select_cohort(model: SplitModel, params: PyTree, xs: jnp.ndarray,
                  ys: jnp.ndarray, keys: jax.Array, cfg: FLConfig,
                  num_classes: int, *, chunk_size: int = 0,
                  mesh: Optional[Mesh] = None, gather: bool = False):
    """Batched Extract&Selection for a stacked cohort.

    keys are the per-client ROUND keys (each client's selection key is
    derived exactly as ``rounds.client_round`` derives its own, so stacked
    and sequential rounds select identically). ``chunk_size > 0`` streams
    the cohort through the pipeline ``chunk_size`` clients at a time (on a
    ``mesh`` with a ``data`` axis wider than 1, each chunk's client axis is
    additionally sharded across devices; the chunk is rounded up to a
    multiple of the axis so full chunks carry no pad clients — a ragged
    FINAL chunk still pads up to the axis).

    gather=False returns (acts (B, N, ...), Selection) — the full cohort's
    activation stack, so only the per-chunk PIPELINE intermediates are
    bounded. gather=True returns the per-client metadata
    (sel_acts (B, CK, ...), sel_ys (B, CK), valid (B, CK),
    lloyd_iters (B,)) with each
    chunk's activations/features gathered down and DROPPED before the next
    chunk runs — the mega-cohort mode, where device memory holds the input
    stack plus one chunk's activations, never the cohort's.
    """
    b = xs.shape[0]
    sel_keys = jax.vmap(lambda k: jax.random.split(k)[0])(jnp.asarray(keys))
    use_mesh = mesh if data_axis_size(mesh) > 1 else None
    if use_mesh is not None and 0 < chunk_size < b:
        ndev = data_axis_size(use_mesh)
        chunk_size = -(-chunk_size // ndev) * ndev

    take0 = jax.vmap(lambda a, i: jnp.take(a, i, axis=0))

    def one(lo, hi):
        if use_mesh is not None:
            acts, sels = _select_stack_sharded(
                model, params, xs[lo:hi], ys[lo:hi], sel_keys[lo:hi], cfg,
                num_classes, use_mesh)
        else:
            acts, sels = _select_stack(model, params, xs[lo:hi], ys[lo:hi],
                                       sel_keys[lo:hi], cfg, num_classes)
        if gather:
            return (take0(acts, sels.indices), take0(ys[lo:hi], sels.indices),
                    sels.valid, sels.lloyd_iters)
        return acts, sels

    if chunk_size <= 0 or chunk_size >= b:
        return one(0, b)
    parts = [one(lo, min(lo + chunk_size, b))
             for lo in range(0, b, chunk_size)]
    if gather:
        return tuple(jnp.concatenate(fs, axis=0) for fs in zip(*parts))
    acts = jnp.concatenate([a for a, _ in parts], axis=0)
    sel = Selection(*(jnp.concatenate(fs, axis=0)
                      for fs in zip(*(s for _, s in parts))))
    return acts, sel


def select_metadata_sharded(acts: jnp.ndarray, labels: Optional[jnp.ndarray],
                            keys: jax.Array, mesh: Mesh,
                            **kwargs) -> Selection:
    """shard_map of the §3.1 pipeline over PRECOMPUTED activation stacks:
    the client axis of (B, N, ...) acts splits over the mesh's ``data``
    axis, each device lax.maps its shard (bit-identical to the sequential
    loop — see ``_select_stack_sharded``). The round engine fuses the lower
    forward in; this is the acts-level entry the selection benchmark
    shards. ``kwargs`` are ``select_metadata``'s static knobs."""
    ndev = data_axis_size(mesh)
    (acts, keys, labels), unpad = _pad_clients(
        (acts, jnp.asarray(keys), labels), ndev)

    def one(args):
        a, y, k = args
        return select_metadata(a, y, k, **kwargs)

    if labels is None:
        fn = shard_map(
            lambda a, k: jax.lax.map(lambda t: select_metadata(
                t[0], None, t[1], **kwargs), (a, k)),
            mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=P("data"), check_rep=False)
        sels = fn(acts, keys)
    else:
        fn = shard_map(lambda a, y, k: jax.lax.map(one, (a, y, k)),
                       mesh=mesh,
                       in_specs=(P("data"), P("data"), P("data")),
                       out_specs=P("data"), check_rep=False)
        sels = fn(acts, labels, keys)
    return unpad(sels)


# --------------------------------------------------------------------------
# LocalUpdate over a stacked cohort (§3.2)
# --------------------------------------------------------------------------
@profiled_jit(name="local_update_stack", static_argnames=("model", "cfg"))
def _local_update_stack(model: SplitModel, cfg: FLConfig, params: PyTree,
                        xs: jnp.ndarray, ys: jnp.ndarray, keys: jax.Array):
    """The single-host stacked LocalUpdate as one compiled function
    (``model``/``cfg`` are frozen dataclasses, so they key the jit cache
    as statics — the profiled wrapper's recompilation sentinel then
    catches any per-round cache miss on the cohort hot path)."""
    from repro.core.rounds import local_batches  # lazy: rounds imports us
    from repro.optim import sgd
    opt = sgd(cfg.local_lr)

    def one(args):
        x, y, key = args
        k_loc = jax.random.split(key)[1]
        bx, by = local_batches(x, y, k_loc, cfg)
        new_p, _, losses = fa.local_update(
            params, opt, opt.init(params), (bx, by),
            lambda p, b: model.loss(p, b))
        return new_p, losses.mean()

    return jax.lax.map(one, (xs, ys, keys))


def local_update_cohort(model: SplitModel, params: PyTree, xs: jnp.ndarray,
                        ys: jnp.ndarray, keys: jax.Array, cfg: FLConfig,
                        mesh: Optional[Mesh] = None):
    """LocalUpdate over the stacked cohort in ONE compiled computation:
    every client starts from the same global params, shuffles with its own
    key (same derivation as ``rounds.client_round``), and runs the same SGD
    scan. Returns (stacked client params with leading B axis, (B,) losses).

    The client axis is ``lax.map``-ed, not vmapped: vmap re-batches the
    convolution *gradients* into different reduction orders (~1e-7 drift vs
    the sequential loop), while lax.map keeps each client's HLO identical —
    bit-identical results with the Python-loop dispatch overhead still gone.
    Cross-client parallelism comes from ``mesh`` instead: shard_map splits
    the client axis over the ``data`` axis and each device maps its shard."""
    keys = jnp.asarray(keys)

    if data_axis_size(mesh) > 1:
        from repro.core.rounds import local_batches  # lazy: rounds imports us
        from repro.optim import sgd
        opt = sgd(cfg.local_lr)

        def one(args):
            x, y, key = args
            k_loc = jax.random.split(key)[1]
            bx, by = local_batches(x, y, k_loc, cfg)
            new_p, _, losses = fa.local_update(
                params, opt, opt.init(params), (bx, by),
                lambda p, b: model.loss(p, b))
            return new_p, losses.mean()

        (xs, ys, keys), unpad = _pad_clients((xs, ys, keys),
                                             data_axis_size(mesh))
        fn = shard_map(lambda x, y, k: jax.lax.map(one, (x, y, k)),
                       mesh=mesh, in_specs=(P("data"), P("data"), P("data")),
                       out_specs=P("data"), check_rep=False)
        return unpad(fn(xs, ys, keys))

    return _local_update_stack(model, cfg, params, xs, ys, keys)


# --------------------------------------------------------------------------
# the client side of a round, stacked end to end
# --------------------------------------------------------------------------
def cohort_round(model: SplitModel, params: PyTree,
                 clients: List[ClientData], cfg: FLConfig, keys: jax.Array,
                 ledger: CommLedger, num_classes: int, *,
                 mesh: Optional[Mesh] = None,
                 chunk_size: Optional[int] = None,
                 channel=None, client_ids=None):
    """Everything the cohort's clients do in one round — chunked/sharded
    Extract&Selection plus the stacked LocalUpdate — with the same
    transport-charged ledger accounting as ``rounds.client_round``: the
    gathered (sel_acts, sel_y, valid) triple is encoded through the cohort
    entry of the transport ``channel`` (one vmapped quantize for the int8
    codec — the stack never unbatches for the hot path, only for framing),
    each UpperUpdate frame is charged per client at its exact size, and the
    metadata handed to the server is the DECODED wire content (None where a
    faulty channel lost the frame). Returns per-client lists
    (params, metadata, loss) interchangeable with the sequential loop's —
    including byte-identical ledger totals, and identical injected faults:
    the channel keys its randomness on the GLOBAL ``client_ids``, not on
    engine call order."""
    from repro.fl import transport as T
    assert cfg.use_selection, (
        "cohort_round implements the selection path only; the Table-2 "
        "upload-everything baseline (use_selection=False) runs through the "
        "sequential client_round loop")
    stacked = cohort_arrays(clients)
    assert stacked is not None, "cohort_round requires a stackable cohort"
    xs, ys = stacked
    b = len(clients)
    if chunk_size is None:
        chunk_size = cfg.selection_chunk_size
    if chunk_size <= 0:
        chunk_size = auto_chunk_size(
            model, params, xs.shape[1:], xs.dtype, b,
            data_axis=data_axis_size(mesh))

    if channel is None:
        channel = T.Channel(ledger, checksum=cfg.transport_checksum)
    if client_ids is None:
        client_ids = list(range(b))

    with obs.span("select", clients=b) as ssp:
        sel_acts, sel_ys, valid, lloyd_iters = select_cohort(
            model, params, xs, ys, keys, cfg, num_classes,
            chunk_size=chunk_size, mesh=mesh, gather=True)
        ssp.sync(valid)
        if ssp.enabled:
            from repro.core.rounds import emit_selection_sketch
            vnp = np.asarray(valid)
            ssp.set(selected=int(vnp.sum()),
                    lloyd_iters=np.asarray(lloyd_iters).tolist())
            for i, cid in enumerate(client_ids):
                emit_selection_sketch(vnp[i], num_classes,
                                      cfg.clusters_per_class, int(cid),
                                      xs[i].shape[0])

    with obs.span("transport", clients=b) as tsp:
        metadatas = tsp.sync(channel.upload_knowledge_batched(
            [int(c) for c in client_ids], sel_acts, sel_ys, valid,
            T.knowledge_codec(cfg)))

    with obs.span("local_update", clients=b) as lsp:
        cparams, losses = local_update_cohort(model, params, xs, ys, keys,
                                              cfg, mesh=mesh)
        lsp.sync(cparams)
    client_params = [jax.tree.map(lambda a, i=i: a[i], cparams)
                     for i in range(b)]
    with obs.span("transport", clients=b):
        for cid, p in zip(client_ids, client_params):
            channel.upload_update(int(cid), p)
    return client_params, metadatas, [float(l) for l in np.asarray(losses)]


def run_round_distributed(model: SplitModel, global_params: PyTree,
                          upper_init: PyTree, clients: List[ClientData],
                          cfg: FLConfig, key: jax.Array,
                          ledger: Optional[CommLedger] = None,
                          num_classes: int = 10,
                          mesh: Optional[Mesh] = None):
    """Algorithm 1 with the client side stacked (``cohort_round``) and the
    seed's server side (``rounds.server_round``) — bit-identical to
    ``rounds.run_round`` on the same key. Requires ``cfg.use_selection``
    and a stackable cohort (callers fall back to the sequential loop
    otherwise)."""
    from repro.core import rounds as R
    ledger = ledger if ledger is not None else CommLedger()
    keys = jax.random.split(key, len(clients) + 1)
    client_params, metadatas, losses = cohort_round(
        model, global_params, clients, cfg, keys[:-1], ledger, num_classes,
        mesh=mesh)
    res = R.server_round(model, global_params, upper_init, client_params,
                         metadatas, cfg, keys[-1])
    res.client_losses = losses
    res.total_samples = sum(len(c.data) for c in clients)
    return res
