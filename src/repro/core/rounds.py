"""Algorithm 1 (paper): one global round of Split Training with Metadata
Selection, at simulator granularity (the pod-scale stacked/sharded variant
lives in ``repro.core.distributed``). LocalUpdate still loops clients in
Python, but Extract&Selection — the hot path — is batched: when the cohort's
data shapes agree, ``select_for_clients`` stacks the clients and runs the
lower forward plus the whole §3.1 pipeline under one ``vmap``.

    for each client k:
        M_Ck loads W_G(t-1)
        D_Mk(t)  <- Extract&Selection(D_k, W_G^l(t-1))          # §3.1
        W_Ck(t)  <- LocalUpdate(D_k, W_G(t-1))                  # §3.2
    server:
        D_M(t)   <- U_k D_Mk(t)
        W_S^u(t) <- MetaTraining(D_M(t), W_G^u(0))              # §3.3
        M_COM(t) <- ModelCompose(W_G^l(t-1), W_S^u(t))
        test M_COM(t)
        W_G(t)   <- WeightAverage(W_Ck(t))                      # Eq. 2
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import fedavg as fa
from repro.core import meta_training as mt
from repro.core.selection import (Selection, select_metadata,
                                  select_metadata_batched)
from repro.core.split import SplitModel
from repro.data.partition import ClientData
from repro.fl.comms import CommLedger
from repro.optim import sgd

PyTree = Any

# Batched selection stacks the whole cohort's data + activations on one
# device; past this many stacked input elements (~1 GiB f32) fall back to
# the sequential per-client path instead of risking an OOM the seed's
# per-client loop never had. (Chunked streaming is a ROADMAP item.)
MAX_BATCHED_ELEMENTS = 1 << 28


@dataclass
class RoundResult:
    global_params: PyTree            # W_G(t)
    composed_params: PyTree          # M_COM(t)
    upper_trained: PyTree            # W_S^u(t)
    metadata_count: int              # |D_M(t)|
    total_samples: int               # sum_k |D_k|
    client_losses: List[float] = field(default_factory=list)
    meta_losses: Optional[np.ndarray] = None


def select_for_clients(model: SplitModel, params: PyTree,
                       clients: List[ClientData], cfg: FLConfig,
                       keys: jax.Array, num_classes: int):
    """Batched Extract&Selection: stack the cohort, vmap the lower forward
    and the whole §3.1 pipeline across clients in one call — replacing the
    per-client Python loop's selections. ``keys`` are the per-client round
    keys; each client's selection key matches what ``client_round`` would
    derive on its own, so batched and sequential rounds are identical.

    Returns a list of (x_k, y_k, acts_k, Selection_k) per client (the
    device-resident arrays are threaded through so ``client_round`` does
    not re-transfer them), or None when the cohort is ragged (different
    data shapes) or its stacked inputs + activations exceed
    MAX_BATCHED_ELEMENTS — callers then fall back to the sequential
    path."""
    if not cfg.use_selection or not cfg.batched_selection:
        return None
    if len({(c.data.x.shape, c.data.y.shape) for c in clients}) != 1:
        return None
    x_shape = clients[0].data.x.shape
    act_shape = jax.eval_shape(
        lambda x: model.apply_lower(params, x),
        jax.ShapeDtypeStruct(x_shape, jnp.float32)).shape
    stacked = len(clients) * (int(np.prod(x_shape))
                              + int(np.prod(act_shape)))
    if stacked > MAX_BATCHED_ELEMENTS:
        return None
    xs = jnp.stack([jnp.asarray(c.data.x) for c in clients])
    ys = jnp.stack([jnp.asarray(c.data.y) for c in clients])
    sel_keys = jax.vmap(lambda k: jax.random.split(k)[0])(jnp.asarray(keys))
    acts = jax.vmap(lambda x: model.apply_lower(params, x))(xs)
    sels = select_metadata_batched(
        acts, ys, sel_keys, num_classes=num_classes,
        clusters_per_class=cfg.clusters_per_class,
        pca_components=cfg.pca_components, kmeans_iters=cfg.kmeans_iters,
        use_pallas=cfg.use_pallas_selection, pca_solver=cfg.pca_solver)
    return [(xs[i], ys[i], acts[i],
             Selection(sels.indices[i], sels.valid[i], sels.features[i]))
            for i in range(len(clients))]


def client_round(model: SplitModel, params: PyTree, client: ClientData,
                 cfg: FLConfig, key: jax.Array, ledger: CommLedger,
                 num_classes: int, precomputed=None):
    """Client k's work: Extract&Selection + LocalUpdate. ``precomputed`` is
    an optional (x, y, acts, Selection) tuple from ``select_for_clients``
    (already on device)."""
    if precomputed is not None:
        x, y, acts, sel = precomputed
    else:
        x, y = jnp.asarray(client.data.x), jnp.asarray(client.data.y)
        acts = sel = None
    k_sel, k_loc = jax.random.split(key)

    # ---- Extract & Selection (uses ONLY the lower part W_G^l(t-1)) ----
    metadata = None
    if cfg.use_selection:
        if sel is None:
            acts = model.apply_lower(params, x)                   # A_k^[j]
            sel = select_metadata(
                acts, y, k_sel, num_classes=num_classes,
                clusters_per_class=cfg.clusters_per_class,
                pca_components=cfg.pca_components,
                kmeans_iters=cfg.kmeans_iters,
                use_pallas=cfg.use_pallas_selection,
                pca_solver=cfg.pca_solver)
        sel_acts = jnp.take(acts, sel.indices, axis=0)
        sel_y = jnp.take(y, sel.indices, axis=0)
        metadata = (sel_acts, sel_y, sel.valid)
        ledger.upload("metadata", sel_acts[sel.valid].size * 4
                      + int(sel.valid.sum()) * 4)
    else:
        # Table 2 baseline: ALL activation maps are uploaded.
        acts = model.apply_lower(params, x)
        metadata = (acts, y, jnp.ones((x.shape[0],), bool))
        ledger.upload("metadata", acts.size * 4 + y.size * 4)

    # ---- LocalUpdate ----
    bs = min(cfg.local_batch_size, x.shape[0])
    steps_per_epoch = max(x.shape[0] // bs, 1)
    perm = jax.random.permutation(k_loc, x.shape[0])
    perm = jnp.tile(perm, cfg.local_epochs)[: cfg.local_epochs * steps_per_epoch * bs]
    bx = x[perm].reshape((-1, bs) + x.shape[1:])
    by = y[perm].reshape(-1, bs)
    opt = sgd(cfg.local_lr)
    new_params, _, losses = fa.local_update(
        params, opt, opt.init(params), (bx, by),
        lambda p, b: model.loss(p, b))
    ledger.upload("weights", sum(a.size * 4 for a in jax.tree.leaves(new_params)))
    return new_params, metadata, float(losses.mean())


def server_round(model: SplitModel, prev_global: PyTree, upper_init: PyTree,
                 client_params: List[PyTree], metadatas: List[tuple],
                 cfg: FLConfig, key: jax.Array) -> RoundResult:
    """Server's work: aggregate metadata, MetaTraining, ModelCompose, Eq. 2."""
    acts = jnp.concatenate([m[0] for m in metadatas], 0)
    ys = jnp.concatenate([m[1] for m in metadatas], 0)
    valid = jnp.concatenate([m[2] for m in metadatas], 0)

    upper, meta_losses = mt.meta_train(
        upper_init, model.upper_loss, acts, ys,
        epochs=cfg.meta_epochs, batch_size=cfg.meta_batch_size,
        lr=cfg.meta_lr, l2=cfg.meta_l2, key=key, valid=valid)

    # ModelCompose: lower layers from W_G^l(t-1), upper from W_S^u(t)
    composed = model.merge(model.split(prev_global)[0], upper)
    new_global = fa.weight_average(client_params)
    return RoundResult(
        global_params=new_global, composed_params=composed,
        upper_trained=upper, metadata_count=int(valid.sum()),
        total_samples=0, meta_losses=np.asarray(meta_losses))


def run_round(model: SplitModel, global_params: PyTree, upper_init: PyTree,
              clients: List[ClientData], cfg: FLConfig, key: jax.Array,
              ledger: Optional[CommLedger] = None,
              num_classes: int = 10) -> RoundResult:
    ledger = ledger if ledger is not None else CommLedger()
    keys = jax.random.split(key, len(clients) + 1)
    pre = select_for_clients(model, global_params, clients, cfg,
                             keys[:-1], num_classes)
    client_params, metadatas, losses = [], [], []
    for i, (c, k) in enumerate(zip(clients, keys[:-1])):
        p, m, l = client_round(model, global_params, c, cfg, k, ledger,
                               num_classes,
                               precomputed=None if pre is None else pre[i])
        client_params.append(p)
        metadatas.append(m)
        losses.append(l)
    res = server_round(model, global_params, upper_init, client_params,
                       metadatas, cfg, keys[-1])
    res.client_losses = losses
    res.total_samples = sum(len(c.data) for c in clients)
    return res
