"""Algorithm 1 (paper): one global round of Split Training with Metadata
Selection, at simulator granularity (explicit per-client loop; the pod-scale
stacked/sharded variant lives in ``repro.core.distributed``).

    for each client k:
        M_Ck loads W_G(t-1)
        D_Mk(t)  <- Extract&Selection(D_k, W_G^l(t-1))          # §3.1
        W_Ck(t)  <- LocalUpdate(D_k, W_G(t-1))                  # §3.2
    server:
        D_M(t)   <- U_k D_Mk(t)
        W_S^u(t) <- MetaTraining(D_M(t), W_G^u(0))              # §3.3
        M_COM(t) <- ModelCompose(W_G^l(t-1), W_S^u(t))
        test M_COM(t)
        W_G(t)   <- WeightAverage(W_Ck(t))                      # Eq. 2
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import fedavg as fa
from repro.core import meta_training as mt
from repro.core.selection import Selection, select_metadata
from repro.core.split import SplitModel
from repro.data.partition import ClientData
from repro.fl.comms import CommLedger
from repro.optim import sgd

PyTree = Any


@dataclass
class RoundResult:
    global_params: PyTree            # W_G(t)
    composed_params: PyTree          # M_COM(t)
    upper_trained: PyTree            # W_S^u(t)
    metadata_count: int              # |D_M(t)|
    total_samples: int               # sum_k |D_k|
    client_losses: List[float] = field(default_factory=list)
    meta_losses: Optional[np.ndarray] = None


def client_round(model: SplitModel, params: PyTree, client: ClientData,
                 cfg: FLConfig, key: jax.Array, ledger: CommLedger,
                 num_classes: int):
    """Client k's work: Extract&Selection + LocalUpdate."""
    x, y = jnp.asarray(client.data.x), jnp.asarray(client.data.y)
    k_sel, k_loc = jax.random.split(key)

    # ---- Extract & Selection (uses ONLY the lower part W_G^l(t-1)) ----
    metadata = None
    if cfg.use_selection:
        acts = model.apply_lower(params, x)                       # A_k^[j]
        sel: Selection = select_metadata(
            acts, y, k_sel, num_classes=num_classes,
            clusters_per_class=cfg.clusters_per_class,
            pca_components=cfg.pca_components,
            kmeans_iters=cfg.kmeans_iters)
        sel_acts = jnp.take(acts, sel.indices, axis=0)
        sel_y = jnp.take(y, sel.indices, axis=0)
        metadata = (sel_acts, sel_y, sel.valid)
        ledger.upload("metadata", sel_acts[sel.valid].size * 4
                      + int(sel.valid.sum()) * 4)
    else:
        # Table 2 baseline: ALL activation maps are uploaded.
        acts = model.apply_lower(params, x)
        metadata = (acts, y, jnp.ones((x.shape[0],), bool))
        ledger.upload("metadata", acts.size * 4 + y.size * 4)

    # ---- LocalUpdate ----
    bs = min(cfg.local_batch_size, x.shape[0])
    steps_per_epoch = max(x.shape[0] // bs, 1)
    perm = jax.random.permutation(k_loc, x.shape[0])
    perm = jnp.tile(perm, cfg.local_epochs)[: cfg.local_epochs * steps_per_epoch * bs]
    bx = x[perm].reshape((-1, bs) + x.shape[1:])
    by = y[perm].reshape(-1, bs)
    opt = sgd(cfg.local_lr)
    new_params, _, losses = fa.local_update(
        params, opt, opt.init(params), (bx, by),
        lambda p, b: model.loss(p, b))
    ledger.upload("weights", sum(a.size * 4 for a in jax.tree.leaves(new_params)))
    return new_params, metadata, float(losses.mean())


def server_round(model: SplitModel, prev_global: PyTree, upper_init: PyTree,
                 client_params: List[PyTree], metadatas: List[tuple],
                 cfg: FLConfig, key: jax.Array) -> RoundResult:
    """Server's work: aggregate metadata, MetaTraining, ModelCompose, Eq. 2."""
    acts = jnp.concatenate([m[0] for m in metadatas], 0)
    ys = jnp.concatenate([m[1] for m in metadatas], 0)
    valid = jnp.concatenate([m[2] for m in metadatas], 0)

    upper, meta_losses = mt.meta_train(
        upper_init, model.upper_loss, acts, ys,
        epochs=cfg.meta_epochs, batch_size=cfg.meta_batch_size,
        lr=cfg.meta_lr, l2=cfg.meta_l2, key=key, valid=valid)

    # ModelCompose: lower layers from W_G^l(t-1), upper from W_S^u(t)
    composed = model.merge(model.split(prev_global)[0], upper)
    new_global = fa.weight_average(client_params)
    return RoundResult(
        global_params=new_global, composed_params=composed,
        upper_trained=upper, metadata_count=int(valid.sum()),
        total_samples=0, meta_losses=np.asarray(meta_losses))


def run_round(model: SplitModel, global_params: PyTree, upper_init: PyTree,
              clients: List[ClientData], cfg: FLConfig, key: jax.Array,
              ledger: Optional[CommLedger] = None,
              num_classes: int = 10) -> RoundResult:
    ledger = ledger if ledger is not None else CommLedger()
    keys = jax.random.split(key, len(clients) + 1)
    client_params, metadatas, losses = [], [], []
    for c, k in zip(clients, keys[:-1]):
        p, m, l = client_round(model, global_params, c, cfg, k, ledger,
                               num_classes)
        client_params.append(p)
        metadatas.append(m)
        losses.append(l)
    res = server_round(model, global_params, upper_init, client_params,
                       metadatas, cfg, keys[-1])
    res.client_losses = losses
    res.total_samples = sum(len(c.data) for c in clients)
    return res
