"""Algorithm 1 (paper): one global round of Split Training with Metadata
Selection, at simulator granularity. The pod-scale engine — ``shard_map``
over the mesh's data axis, chunked mega-cohort streaming, and the stacked
LocalUpdate — lives in ``repro.core.distributed``; this module
delegates to it when ``cfg.distributed_selection`` is set (and for the
chunked path whenever a cohort's stack would exceed the one-device memory
budget). Extract&Selection — the hot path — is batched either way: when the
cohort's data shapes agree, ``select_for_clients`` stacks the clients and
runs the lower forward plus the whole §3.1 pipeline under one ``vmap``.

    for each client k:
        M_Ck loads W_G(t-1)
        D_Mk(t)  <- Extract&Selection(D_k, W_G^l(t-1))          # §3.1
        W_Ck(t)  <- LocalUpdate(D_k, W_G(t-1))                  # §3.2
    server:
        D_M(t)   <- U_k D_Mk(t)
        W_S^u(t) <- MetaTraining(D_M(t), W_G^u(0))              # §3.3
        M_COM(t) <- ModelCompose(W_G^l(t-1), W_S^u(t))
        test M_COM(t)
        W_G(t)   <- WeightAverage(W_Ck(t))                      # Eq. 2
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core import fedavg as fa
from repro.core import meta_training as mt
from repro.core.selection import Selection, select_metadata
from repro.core.split import SplitModel
from repro.data.partition import ClientData
from repro.fl.comms import CommLedger
from repro.optim import sgd

PyTree = Any

# Batched selection stacks a chunk of the cohort's data + activations on one
# device; past this many stacked input elements (~1 GiB f32) the cohort is
# STREAMED through the pipeline in client chunks sized to fit the budget
# (repro.core.distributed.select_cohort) — chunking is a pure schedule, so
# results stay bit-identical to the one-stack (and sequential) path.
MAX_BATCHED_ELEMENTS = 1 << 28


@dataclass
class RoundResult:
    global_params: PyTree            # W_G(t)
    composed_params: PyTree          # M_COM(t)
    upper_trained: PyTree            # W_S^u(t)
    metadata_count: int              # |D_M(t)|
    total_samples: int               # sum_k |D_k|
    client_losses: List[float] = field(default_factory=list)
    meta_losses: Optional[np.ndarray] = None


def select_for_clients(model: SplitModel, params: PyTree,
                       clients: List[ClientData], cfg: FLConfig,
                       keys: jax.Array, num_classes: int, mesh=None):
    """Batched Extract&Selection: stack the cohort, vmap the lower forward
    and the whole §3.1 pipeline across clients in one call — replacing the
    per-client Python loop's selections. ``keys`` are the per-client round
    keys; each client's selection key matches what ``client_round`` would
    derive on its own, so batched and sequential rounds are identical.

    A cohort whose stacked inputs + activations exceed MAX_BATCHED_ELEMENTS
    (or with ``cfg.selection_chunk_size`` set) is streamed through the
    pipeline in client chunks by ``distributed.select_cohort`` — identical
    results, with each chunk's activations/features gathered down to the
    selected metadata and dropped before the next chunk runs. ``mesh`` (a
    mesh with a ``data`` axis) shards the client axis across devices with
    ``shard_map``.

    Returns a list of (x_k, y_k, (sel_acts_k, sel_y_k, valid_k),
    lloyd_iters_k) per
    client (device-resident, so ``client_round`` neither re-transfers nor
    re-selects), or None when selection/batching is off or the cohort is
    ragged (different data shapes) — callers then fall back to the
    sequential path."""
    from repro.core import distributed as D
    if not cfg.use_selection or not cfg.batched_selection:
        return None
    if not D.cohort_is_stackable(clients):
        return None
    if not D.cohort_inputs_fit(clients):
        return None
    x_shape = clients[0].data.x.shape
    x_dtype = jax.dtypes.canonicalize_dtype(
        np.asarray(clients[0].data.x).dtype)
    chunk = cfg.selection_chunk_size
    if chunk <= 0:
        chunk = D.auto_chunk_size(model, params, x_shape, x_dtype,
                                  len(clients),
                                  data_axis=D.data_axis_size(mesh))
    xs, ys = D.cohort_arrays(clients)
    with obs.span("select", clients=len(clients), batched=True) as ssp:
        sel_acts, sel_ys, valid, lloyd_iters = D.select_cohort(
            model, params, xs, ys, keys, cfg, num_classes, chunk_size=chunk,
            mesh=mesh, gather=True)
        ssp.sync(valid)
        if ssp.enabled:
            vnp = np.asarray(valid).astype(bool)
            total = int(np.prod(x_shape[:1])) * len(clients)
            ssp.set(selected=int(vnp.sum()),
                    selected_fraction=float(vnp.sum()) / max(total, 1),
                    lloyd_iters=int(np.asarray(lloyd_iters).min()))
    return [(xs[i], ys[i], (sel_acts[i], sel_ys[i], valid[i]),
             lloyd_iters[i])
            for i in range(len(clients))]


def emit_selection_sketch(valid, num_classes: int, clusters_per_class: int,
                          client_id: int, n_k: int) -> None:
    """Persist one client's selection sketch into the trace: the class x
    cluster occupancy bitmap (which §3.1 slots produced a representative)
    plus the selected fraction |D_Mk|/|D_k|. Emitted BEFORE the transport
    encode — the wire compacts the bitmap to the valid rows, so this is
    the only place the (CK,) slot structure still exists. The event nests
    under the open ``select`` span, so the round index is its ancestry."""
    v = np.asarray(valid).astype(bool).reshape(-1)
    if v.size != num_classes * clusters_per_class:
        return   # Table-2 baseline ships a per-sample mask, not slots
    obs.event("selection_sketch", client=int(client_id),
              occupancy=v.reshape(num_classes,
                                  clusters_per_class).astype(int).tolist(),
              selected=int(v.sum()),
              selected_fraction=float(v.sum() / max(n_k, 1)))


def epoch_permutations(key: jax.Array, n: int, epochs: int) -> jnp.ndarray:
    """(epochs, n) shuffle orders for LocalUpdate: epoch 0 keeps the seed's
    stream (``permutation(key, n)``); every later epoch folds its index into
    the key for a FRESH permutation. (The seed replayed epoch 0's order
    every epoch via ``jnp.tile`` — multi-epoch SGD saw one fixed batch
    order.)"""
    ks = jax.vmap(lambda e: jax.random.fold_in(key, e))(jnp.arange(epochs))
    ks = ks.at[0].set(key)
    return jax.vmap(lambda k: jax.random.permutation(k, n))(ks)


def local_batches(x: jnp.ndarray, y: jnp.ndarray, k_loc: jax.Array,
                  cfg: FLConfig):
    """Shuffle + batch one client's data for LocalUpdate: (steps, bs, ...)
    with a fresh permutation each local epoch. Shared by the sequential
    ``client_round`` and the stacked ``distributed.local_update_cohort`` so
    both paths batch identically."""
    n = x.shape[0]
    bs = min(cfg.local_batch_size, n)
    steps_per_epoch = max(n // bs, 1)
    perm = epoch_permutations(k_loc, n, cfg.local_epochs)
    perm = perm[:, :steps_per_epoch * bs].reshape(-1)
    bx = x[perm].reshape((-1, bs) + x.shape[1:])
    by = y[perm].reshape(-1, bs)
    return bx, by


def client_round(model: SplitModel, params: PyTree, client: ClientData,
                 cfg: FLConfig, key: jax.Array, ledger: CommLedger,
                 num_classes: int, precomputed=None, channel=None,
                 client_id: int = 0):
    """Client k's work: Extract&Selection + LocalUpdate. ``precomputed`` is
    an optional (x, y, (sel_acts, sel_y, valid)) tuple from
    ``select_for_clients`` (already on device).

    Both uploads flow through a transport ``channel`` (a perfect wire by
    default; ``repro.fl.faults.FaultyChannel`` injects crashes/corruption):
    the ledger is charged the exact frame bytes, and the metadata handed
    back is what the server DECODES (valid rows only, dequantized under a
    lossy ``cfg.transport_codec``) — or None when the frame never survived
    the wire. ``client_id`` is the client's GLOBAL index: the fault
    runtime keys its per-(round, client) randomness on it, which is what
    makes injected faults identical across engines."""
    from repro.fl import transport as T
    if channel is None:
        channel = T.Channel(ledger, checksum=cfg.transport_checksum)
    lloyd_it = None
    if precomputed is not None:
        if len(precomputed) == 4:      # select_for_clients adds lloyd_iters
            x, y, metadata, lloyd_it = precomputed
        else:
            x, y, metadata = precomputed
    else:
        x, y = jnp.asarray(client.data.x), jnp.asarray(client.data.y)
        metadata = None
    k_sel, k_loc = jax.random.split(key)

    with obs.span("client", client=int(client_id)) as csp:
        # ---- Extract & Selection (uses ONLY the lower part W_G^l(t-1)) --
        codec = T.knowledge_codec(cfg)
        with obs.span("select") as ssp:
            if cfg.use_selection:
                if metadata is None:
                    acts = model.apply_lower(params, x)           # A_k^[j]
                    sel = select_metadata(
                        acts, y, k_sel, num_classes=num_classes,
                        clusters_per_class=cfg.clusters_per_class,
                        pca_components=cfg.pca_components,
                        kmeans_iters=cfg.kmeans_iters,
                        use_pallas=cfg.use_pallas_selection,
                        pca_solver=cfg.pca_solver)
                    metadata = (jnp.take(acts, sel.indices, axis=0),
                                jnp.take(y, sel.indices, axis=0), sel.valid)
                    lloyd_it = sel.lloyd_iters
                if ssp.enabled:
                    emit_selection_sketch(metadata[2], num_classes,
                                          cfg.clusters_per_class,
                                          client_id, x.shape[0])
                metadata = ssp.sync(
                    channel.upload_knowledge(client_id, *metadata, codec))
            else:
                # Table 2 baseline: ALL activation maps are uploaded.
                acts = model.apply_lower(params, x)
                metadata = ssp.sync(channel.upload_knowledge(
                    client_id, acts, y, jnp.ones((x.shape[0],), bool),
                    codec))
            if ssp.enabled and metadata is not None:
                n_sel = int(np.asarray(metadata[2]).sum())
                ssp.set(selected=n_sel,
                        selected_fraction=n_sel / max(x.shape[0], 1))
                if lloyd_it is not None:
                    ssp.set(lloyd_iters=int(lloyd_it))

        # ---- LocalUpdate ----
        with obs.span("local_update") as lsp:
            bx, by = local_batches(x, y, k_loc, cfg)
            opt = sgd(cfg.local_lr)
            new_params, _, losses = fa.local_update(
                params, opt, opt.init(params), (bx, by),
                lambda p, b: model.loss(p, b))
            lsp.sync(new_params)
            if lsp.enabled:
                lsp.set(steps=int(bx.shape[0]))
        channel.upload_update(client_id, new_params)
        if csp.enabled:
            csp.set(samples=int(x.shape[0]))
    return new_params, metadata, float(losses.mean())


def server_round(model: SplitModel, prev_global: PyTree, upper_init: PyTree,
                 client_params: List[PyTree], metadatas: List[tuple],
                 cfg: FLConfig, key: jax.Array,
                 fedavg_weights: Optional[List[float]] = None) -> RoundResult:
    """Server's work: aggregate metadata, MetaTraining, ModelCompose, Eq. 2.

    ``metadatas`` are the DECODED SelectedKnowledge triples — the transport
    layer sends valid slots only, so per-client row counts vary (and can be
    zero for a client whose every cluster came back empty). A ``None``
    entry is a frame that never survived the wire (client crash or an
    exhausted retry budget): the server aggregates over exactly the
    knowledge that ARRIVED."""
    arrived = [m for m in metadatas if m is not None]
    if arrived:
        acts = jnp.concatenate([m[0] for m in arrived], 0)
        ys = jnp.concatenate([m[1] for m in arrived], 0)
        valid = jnp.concatenate([m[2] for m in arrived], 0)
        nmeta = int(valid.sum())
    else:
        acts = ys = valid = None
        nmeta = 0

    if acts is None or acts.shape[0] == 0:
        # nothing arrived: W_S^u(t) stays W_G^u(0)
        upper, meta_losses = upper_init, jnp.zeros((0,))
    else:
        with obs.span("meta_train", rows=int(acts.shape[0])) as msp:
            upper, meta_losses = mt.meta_train(
                upper_init, model.upper_loss, acts, ys,
                epochs=cfg.meta_epochs, batch_size=cfg.meta_batch_size,
                lr=cfg.meta_lr, l2=cfg.meta_l2, key=key, valid=valid)
            msp.sync(upper)

    # ModelCompose: lower layers from W_G^l(t-1), upper from W_S^u(t)
    composed = model.merge(model.split(prev_global)[0], upper)
    # Eq. 2, renormalized over the clients that count: 0-weight clients
    # straggled past FLServer.deadline or never delivered an update frame;
    # None = every client counts, the exact unweighted mean — bit-identical
    # to the no-deadline perfect-wire path. A round where NO update counts
    # (every client crashed/lost) keeps W_G(t-1): averaging nothing must
    # not destroy the model.
    if fedavg_weights is not None and not any(fedavg_weights):
        new_global = prev_global
    elif not client_params:
        new_global = prev_global
    else:
        new_global = fa.weight_average(client_params,
                                       weights=fedavg_weights)
    return RoundResult(
        global_params=new_global, composed_params=composed,
        upper_trained=upper, metadata_count=nmeta,
        total_samples=0, meta_losses=np.asarray(meta_losses))


def run_cohort(model: SplitModel, params: PyTree,
               clients: List[ClientData], cfg: FLConfig, keys: jax.Array,
               ledger: CommLedger, num_classes: int, mesh=None,
               channel=None, client_ids=None):
    """The client side of one round for a whole cohort, with the engine
    dispatch in ONE place (shared by ``run_round`` and ``FLSimulation``):
    the stacked pod engine (``distributed.cohort_round``) when configured
    and the cohort stacks within budget, else the per-client loop with
    batched-selection precompute. Returns per-client lists
    (params, metadata, loss) — metadata entries are None for frames that
    did not survive a faulty ``channel``.

    ``client_ids`` are the cohort members' GLOBAL indices (defaults to
    cohort position): the fault runtime draws each client's faults from
    (seed, round, id) streams, so whichever engine runs the round — and in
    whatever order — the same clients crash and the same frames corrupt."""
    from repro.core import distributed as D
    if client_ids is None:
        client_ids = list(range(len(clients)))
    if (cfg.distributed_selection and cfg.use_selection
            and D.cohort_is_stackable(clients)
            and D.cohort_inputs_fit(clients)):
        return D.cohort_round(model, params, clients, cfg, keys, ledger,
                              num_classes, mesh=mesh, channel=channel,
                              client_ids=client_ids)
    pre = select_for_clients(model, params, clients, cfg, keys,
                             num_classes, mesh=mesh)
    client_params, metadatas, losses = [], [], []
    for i, (c, k) in enumerate(zip(clients, keys)):
        p, m, l = client_round(model, params, c, cfg, k, ledger,
                               num_classes,
                               precomputed=None if pre is None else pre[i],
                               channel=channel,
                               client_id=int(client_ids[i]))
        client_params.append(p)
        metadatas.append(m)
        losses.append(l)
    return client_params, metadatas, losses


def run_round(model: SplitModel, global_params: PyTree, upper_init: PyTree,
              clients: List[ClientData], cfg: FLConfig, key: jax.Array,
              ledger: Optional[CommLedger] = None,
              num_classes: int = 10, mesh=None) -> RoundResult:
    ledger = ledger if ledger is not None else CommLedger()
    keys = jax.random.split(key, len(clients) + 1)
    client_params, metadatas, losses = run_cohort(
        model, global_params, clients, cfg, keys[:-1], ledger, num_classes,
        mesh=mesh)
    res = server_round(model, global_params, upper_init, client_params,
                       metadatas, cfg, keys[-1])
    res.client_losses = losses
    res.total_samples = sum(len(c.data) for c in clients)
    return res
