"""§3.3 MetaTraining: the server trains the UPPER part of the global model on
the aggregated metadata D_M(t) = U_k D_M_k(t), starting every round from the
initial upper weights W_G^u(0) (the paper does this deliberately to measure
metadata effectiveness in isolation; ``reset_upper_each_round=False`` gives
the warm-start variant we also evaluate).

L2 regularization (paper Tables 6/7) enters as an explicit penalty on the
upper weights.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import Optimizer, apply_l2, sgd

PyTree = Any


def meta_train(upper_init: PyTree,
               upper_loss: Callable[[PyTree, Any, Any], jnp.ndarray],
               acts: jnp.ndarray, targets: Any,
               *, epochs: int, batch_size: int, lr: float,
               l2: float = 0.0, key: Optional[jax.Array] = None,
               valid: Optional[jnp.ndarray] = None,
               opt: Optional[Optimizer] = None) -> tuple:
    """Train upper weights on metadata.

    acts:    (M, ...) selected activation maps (all clients aggregated)
    targets: (M, ...) labels / next-token targets
    valid:   (M,) bool — invalid rows (empty clusters) get zero loss weight.
    Returns (trained_upper, losses (epochs*steps,)).
    """
    m = acts.shape[0]
    bs = min(batch_size, m)
    steps = max(m // bs, 1)
    key = key if key is not None else jax.random.PRNGKey(0)
    w = jnp.ones((m,), jnp.float32) if valid is None else valid.astype(jnp.float32)
    opt = opt or sgd(lr)
    opt_state = opt.init(upper_init)

    def weighted_loss(p, batch):
        a, t, bw = batch
        per = upper_loss(p, a, t)                    # (bs,) per-sample loss
        loss = (per * bw).sum() / jnp.maximum(bw.sum(), 1.0)
        return apply_l2(loss, p, l2)

    def epoch_body(carry, ek):
        p, s = carry
        perm = jax.random.permutation(ek, m)[:steps * bs]
        a = acts[perm].reshape((steps, bs) + acts.shape[1:])
        t = jax.tree.map(
            lambda x: x[perm].reshape((steps, bs) + x.shape[1:]), targets)
        bw = w[perm].reshape(steps, bs)

        def step_body(c, batch):
            p_, s_ = c
            loss, g = jax.value_and_grad(weighted_loss)(p_, batch)
            p_, s_ = opt.apply(g, s_, p_)
            return (p_, s_), loss

        (p, s), losses = jax.lax.scan(step_body, (p, s), (a, t, bw))
        return (p, s), losses

    (upper, _), losses = jax.lax.scan(
        epoch_body, (upper_init, opt_state), jax.random.split(key, epochs))
    return upper, losses.reshape(-1)
