from repro.core.selection import (select_metadata, kmeans, pca_fit,
                                  pca_transform, representatives, Selection)
from repro.core.split import SplitModel
from repro.core.fedavg import (weight_average, weight_average_stacked,
                               local_update, broadcast_to_clients)
from repro.core.meta_training import meta_train
from repro.core.compose import compose, evaluate
from repro.core.rounds import run_round, RoundResult
from repro.core.distributed import (cohort_round, run_round_distributed,
                                    select_cohort, selection_mesh)

__all__ = ["select_metadata", "kmeans", "pca_fit", "pca_transform",
           "representatives", "Selection", "SplitModel", "weight_average",
           "weight_average_stacked", "local_update", "broadcast_to_clients",
           "meta_train", "compose", "evaluate", "run_round", "RoundResult",
           "cohort_round", "run_round_distributed", "select_cohort",
           "selection_mesh"]
