"""The paper's split-network abstraction (§3): a model is partitioned at layer
``j`` into a lower part W^l (generic features, trained by FedAvg) and an upper
part W^u (data-characteristic-sensitive, trained server-side on metadata).

A :class:`SplitModel` bundles the five pure functions every backbone must
provide. Two families implement it:
  * ``repro.models.wrn.make_split_wrn``            (the paper's WRN-40-1)
  * ``repro.models.transformer.make_split_lm``     (the 10 assigned archs)
Both keep layer weights stacked (leading layer axis) so the split is a slice,
FedAvg averages subtrees, and everything scans/shards.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax

PyTree = Any


@dataclass(frozen=True)
class SplitModel:
    """Pure-function bundle. ``params`` is always the FULL model pytree;
    lower/upper are *views* produced by ``split``/undone by ``merge``."""
    config: Any
    split_layer: int
    init: Callable[[jax.Array], PyTree]
    apply: Callable[[PyTree, Any], Any]              # full forward -> logits
    apply_lower: Callable[[PyTree, Any], Any]        # inputs -> activation maps
    apply_upper: Callable[[PyTree, Any], Any]        # activation maps -> logits
    split: Callable[[PyTree], Tuple[PyTree, PyTree]]
    merge: Callable[[PyTree, PyTree], PyTree]
    loss: Callable[[PyTree, Any], Any]               # full-model training loss
    upper_loss: Callable[[PyTree, Any, Any], Any]    # (params, acts, targets)

    def compose(self, lower_src: PyTree, upper_src: PyTree) -> PyTree:
        """Paper §3.3 ModelCompose: lower layers from FedAvg'd W_G^l(t-1),
        upper layers from metadata-trained W_S^u(t)."""
        lower, _ = self.split(lower_src)
        _, upper = self.split(upper_src)
        return self.merge(lower, upper)


def tree_slice_layers(tree: PyTree, start: int, stop: int) -> PyTree:
    """Slice stacked-layer arrays along axis 0 (used by model split fns)."""
    return jax.tree.map(lambda x: x[start:stop], tree)
