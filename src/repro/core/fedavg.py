"""FedAvg (McMahan et al.) — the paper's Eq. (2) and LocalUpdate (§3.2).

Two shapes of the same math:
  * list-of-clients (simulator):   ``weight_average([W_1..W_m])``
  * stacked-clients (pod runtime): params carry a leading client axis G and
    ``weight_average_stacked`` means over it (lowering to one all-reduce when
    G is sharded over the mesh's data axis — DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.optim import Optimizer

PyTree = Any


def weight_average(client_params: Sequence[PyTree],
                   weights: Optional[Sequence[float]] = None) -> PyTree:
    """Eq. 2: W_G(t) = (1/m) sum_k W_Ck(t) (optionally sample-count weighted,
    which is McMahan's original formulation)."""
    m = len(client_params)
    if weights is None:
        w = [1.0 / m] * m
    else:
        tot = float(sum(weights))
        w = [float(x) / tot for x in weights]
    return jax.tree.map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *client_params)


def weight_average_stacked(stacked: PyTree, axis: int = 0) -> PyTree:
    return jax.tree.map(lambda x: jnp.mean(x, axis=axis), stacked)


def broadcast_to_clients(params: PyTree, num_clients: int) -> PyTree:
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_clients,) + x.shape), params)


def local_update(params: PyTree, opt: Optimizer, opt_state: PyTree,
                 batches: Any, loss_fn: Callable[[PyTree, Any], jnp.ndarray],
                 ) -> tuple:
    """§3.2 LocalUpdate: a scan of SGD steps over pre-batched local data.
    ``batches`` is a pytree whose leaves have a leading steps axis."""

    def step(carry, batch):
        p, s = carry
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        p, s = opt.apply(grads, s, p)
        return (p, s), loss

    (params, opt_state), losses = jax.lax.scan(step, (params, opt_state), batches)
    return params, opt_state, losses


def client_drift(client_params: Sequence[PyTree], global_params: PyTree):
    """Diagnostic: mean L2 distance of client weights from the global model
    (grows with non-IID skew; useful in EXPERIMENTS.md)."""
    def dist(cp):
        sq = sum(jnp.sum((a - b).astype(jnp.float32) ** 2)
                 for a, b in zip(jax.tree.leaves(cp),
                                 jax.tree.leaves(global_params)))
        return jnp.sqrt(sq)
    return jnp.stack([dist(cp) for cp in client_params]).mean()
