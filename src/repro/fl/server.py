"""Server role: client sampling, metadata aggregation + MetaTraining +
ModelCompose + WeightAverage, deadline/straggler/quarantine policy.

Downloads go through ``repro.fl.transport``: ``broadcast_weights`` charges
the exact encoded WeightBroadcast frame (native dtypes — the old
``size * 4`` billed bf16/int leaves as f32). ``deadline`` is the
straggler policy: the simulation masks clients whose estimated local time
exceeds it out of WeightAverage instead of waiting (``stragglers`` arg of
``aggregate``).

Fault tolerance generalizes that mask into an ARRIVAL mask: ``aggregate``
zero-weights any client whose UpperUpdate frame did not decode this round
(crash or exhausted retransmit budget) — Eq. 2 renormalizes over the
clients that actually delivered. ``record_arrivals`` tracks per-client
failure streaks; a client that fails ``quarantine_after`` consecutive
rounds is held out of ``sample_clients`` for ``quarantine_cooldown``
rounds (a flapping client should not keep eating cohort slots and
retransmit bytes), then re-admitted. With the policy off (the default) and
every frame arriving, sampling and aggregation are bit-identical to the
perfect-wire path."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence

import jax
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core.rounds import server_round, RoundResult
from repro.core.split import SplitModel
from repro.fl.comms import CommLedger

PyTree = Any


@dataclass
class FLServer:
    model: SplitModel
    global_params: PyTree
    upper_init: PyTree                      # W_G^u(0), reused every round (§3.3)
    cfg: FLConfig
    round_idx: int = 0
    deadline: Optional[float] = None        # seconds; None = wait for all
    ledger: CommLedger = field(default_factory=CommLedger)
    # --- quarantine policy (0 = off) ---
    quarantine_after: int = 0               # K consecutive failed rounds
    quarantine_cooldown: int = 5            # rounds held out once tripped
    fail_streak: dict = field(default_factory=dict)       # cid -> streak
    quarantined_until: dict = field(default_factory=dict)  # cid -> round

    def eligible_clients(self, num_available: int) -> List[int]:
        """Client ids currently allowed into a cohort: everyone whose
        quarantine window (if any) has expired. Quarantine expiring IS the
        re-admission — no separate probation state."""
        return [i for i in range(num_available)
                if self.quarantined_until.get(i, 0) <= self.round_idx]

    def num_quarantined(self, num_available: int) -> int:
        return num_available - len(self.eligible_clients(num_available))

    def sample_clients(self, num_available: int, key: jax.Array) -> np.ndarray:
        """Uniform cohort sampling over the ELIGIBLE clients. When nobody
        is quarantined this takes the exact historical draw (choice over
        ``num_available``) so seeded runs without faults are bit-identical;
        an (unreachable under the policy's own arithmetic, but guarded)
        fully-quarantined population falls back to everyone — an empty
        round would lose more than a flaky cohort."""
        elig = self.eligible_clients(num_available)
        if len(elig) == num_available:
            m = min(self.cfg.clients_per_round, num_available)
            return np.asarray(
                jax.random.choice(key, num_available, (m,), replace=False))
        if not elig:
            elig = list(range(num_available))
        m = min(self.cfg.clients_per_round, len(elig))
        pos = np.asarray(
            jax.random.choice(key, len(elig), (m,), replace=False))
        return np.asarray(elig, dtype=np.int64)[pos]

    def record_arrivals(self, client_ids: Sequence[int],
                        arrived: Sequence[bool]) -> None:
        """Update per-client failure streaks after a round (call after
        ``aggregate``, so ``round_idx`` already names the NEXT round and
        the cooldown window counts from it). A delivered update clears the
        client's streak and any quarantine record."""
        for cid, ok in zip(client_ids, arrived):
            cid = int(cid)
            if ok:
                self.fail_streak.pop(cid, None)
                self.quarantined_until.pop(cid, None)
                continue
            streak = self.fail_streak.get(cid, 0) + 1
            self.fail_streak[cid] = streak
            if self.quarantine_after and streak >= self.quarantine_after:
                self.quarantined_until[cid] = (self.round_idx
                                               + self.quarantine_cooldown)
                self.fail_streak[cid] = 0   # streak restarts post-cooldown

    def broadcast_weights(self, num_clients: int, channel=None) -> int:
        """server -> clients: the cohort downloads W_G(t-1) when it is
        FORMED (so round 0's initial distribution is counted, and every
        broadcast is attributed to the cohort that actually received it —
        it used to be charged post-round against the next cohort's size).
        Charged at the exact WeightBroadcast frame size per member (through
        ``channel`` when given, so checksummed wires bill their CRC
        trailers); returns the bytes charged."""
        from repro.fl import transport as T
        if channel is not None:
            return channel.broadcast_weights(self.global_params, num_clients)
        return T.broadcast_weights(self.ledger, self.global_params,
                                   num_clients)

    def straggler_mask(self, local_times: Sequence[float]) -> Optional[np.ndarray]:
        """Deadline policy: True where a client's estimated local round
        time blows ``deadline`` (the server will not wait for it). None
        when the policy is off or nobody straggled — callers then take the
        exact unweighted-average path. A round where EVERY client straggles
        degenerates to waiting for all (dropping the whole cohort would
        lose the round)."""
        if self.deadline is None:
            return None
        late = np.asarray([t > self.deadline for t in local_times])
        if not late.any() or late.all():
            return None
        return late

    def aggregate(self, client_params: List[PyTree], metadatas: List[tuple],
                  key: jax.Array,
                  stragglers: Optional[np.ndarray] = None,
                  arrived: Optional[np.ndarray] = None,
                  fedavg_weights: Optional[Sequence[float]] = None
                  ) -> RoundResult:
        """``stragglers`` (from ``straggler_mask``) zero-weights the marked
        clients in Eq. 2 — their metadata still counts (Extract&Selection
        is the cheap early phase; it is LocalUpdate that misses the
        deadline). ``arrived`` (from the transport channel) zero-weights
        clients whose UpperUpdate frame never decoded — the generalized
        arrival mask; both None keeps the exact unweighted-mean path. A
        round where no update counts keeps W_G(t-1) (guarded in
        ``server_round``).

        ``fedavg_weights`` overrides the mask-derived 1/0 weights with
        explicit per-client floats — the async service's staleness
        discount (``repro.fl.service.aggregator``). When None (every
        synchronous caller), the historical mask logic runs untouched, so
        existing paths stay bit-identical."""
        if fedavg_weights is not None:
            weights = [float(w) for w in fedavg_weights]
        elif stragglers is None and (arrived is None
                                     or bool(np.all(arrived))):
            weights = None
        else:
            n = len(client_params)
            ok = np.ones(n, bool)
            if stragglers is not None:
                ok &= ~np.asarray(stragglers, bool)
            if arrived is not None:
                ok &= np.asarray(arrived, bool)
            weights = [1.0 if o else 0.0 for o in ok]
        with obs.span("aggregate", clients=len(client_params)) as asp:
            res = server_round(self.model, self.global_params,
                               self.upper_init, client_params, metadatas,
                               self.cfg, key, fedavg_weights=weights)
            asp.sync(res.global_params)
            if asp.enabled:
                asp.set(zero_weighted=(0 if weights is None
                                       else weights.count(0.0)),
                        metadata_count=res.metadata_count)
        self.global_params = res.global_params
        self.round_idx += 1
        return res
