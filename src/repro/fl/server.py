"""Server role: client sampling, metadata aggregation + MetaTraining +
ModelCompose + WeightAverage, deadline/straggler policy."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.core.rounds import server_round, RoundResult
from repro.core.split import SplitModel
from repro.fl.comms import CommLedger

PyTree = Any


@dataclass
class FLServer:
    model: SplitModel
    global_params: PyTree
    upper_init: PyTree                      # W_G^u(0), reused every round (§3.3)
    cfg: FLConfig
    round_idx: int = 0
    deadline: Optional[float] = None        # seconds; None = wait for all
    ledger: CommLedger = field(default_factory=CommLedger)

    def sample_clients(self, num_available: int, key: jax.Array) -> np.ndarray:
        m = min(self.cfg.clients_per_round, num_available)
        return np.asarray(
            jax.random.choice(key, num_available, (m,), replace=False))

    def broadcast_weights(self, num_clients: int) -> int:
        """server -> clients: the cohort downloads W_G(t-1) when it is
        FORMED (so round 0's initial distribution is counted, and every
        broadcast is attributed to the cohort that actually received it —
        it used to be charged post-round against the next cohort's size).
        Returns the bytes charged."""
        nbytes = sum(a.size * 4 for a in jax.tree.leaves(self.global_params))
        self.ledger.download("weights", nbytes * num_clients)
        return nbytes * num_clients

    def aggregate(self, client_params: List[PyTree], metadatas: List[tuple],
                  key: jax.Array) -> RoundResult:
        res = server_round(self.model, self.global_params, self.upper_init,
                           client_params, metadatas, self.cfg, key)
        self.global_params = res.global_params
        self.round_idx += 1
        return res
