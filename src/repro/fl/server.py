"""Server role: client sampling, metadata aggregation + MetaTraining +
ModelCompose + WeightAverage, deadline/straggler policy.

Downloads go through ``repro.fl.transport``: ``broadcast_weights`` charges
the exact encoded WeightBroadcast frame (native dtypes — the old
``size * 4`` billed bf16/int leaves as f32). ``deadline`` is the
straggler policy: the simulation masks clients whose estimated local time
exceeds it out of WeightAverage instead of waiting (``stragglers`` arg of
``aggregate``)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.core.rounds import server_round, RoundResult
from repro.core.split import SplitModel
from repro.fl.comms import CommLedger

PyTree = Any


@dataclass
class FLServer:
    model: SplitModel
    global_params: PyTree
    upper_init: PyTree                      # W_G^u(0), reused every round (§3.3)
    cfg: FLConfig
    round_idx: int = 0
    deadline: Optional[float] = None        # seconds; None = wait for all
    ledger: CommLedger = field(default_factory=CommLedger)

    def sample_clients(self, num_available: int, key: jax.Array) -> np.ndarray:
        m = min(self.cfg.clients_per_round, num_available)
        return np.asarray(
            jax.random.choice(key, num_available, (m,), replace=False))

    def broadcast_weights(self, num_clients: int) -> int:
        """server -> clients: the cohort downloads W_G(t-1) when it is
        FORMED (so round 0's initial distribution is counted, and every
        broadcast is attributed to the cohort that actually received it —
        it used to be charged post-round against the next cohort's size).
        Charged at the exact WeightBroadcast frame size per member; returns
        the bytes charged."""
        from repro.fl import transport as T
        return T.broadcast_weights(self.ledger, self.global_params,
                                   num_clients)

    def straggler_mask(self, local_times: Sequence[float]) -> Optional[np.ndarray]:
        """Deadline policy: True where a client's estimated local round
        time blows ``deadline`` (the server will not wait for it). None
        when the policy is off or nobody straggled — callers then take the
        exact unweighted-average path. A round where EVERY client straggles
        degenerates to waiting for all (dropping the whole cohort would
        lose the round)."""
        if self.deadline is None:
            return None
        late = np.asarray([t > self.deadline for t in local_times])
        if not late.any() or late.all():
            return None
        return late

    def aggregate(self, client_params: List[PyTree], metadatas: List[tuple],
                  key: jax.Array,
                  stragglers: Optional[np.ndarray] = None) -> RoundResult:
        """``stragglers`` (from ``straggler_mask``) zero-weights the marked
        clients in Eq. 2 — their metadata still counts (Extract&Selection
        is the cheap early phase; it is LocalUpdate that misses the
        deadline)."""
        weights = (None if stragglers is None
                   else [0.0 if s else 1.0 for s in stragglers])
        res = server_round(self.model, self.global_params, self.upper_init,
                           client_params, metadatas, self.cfg, key,
                           fedavg_weights=weights)
        self.global_params = res.global_params
        self.round_idx += 1
        return res
