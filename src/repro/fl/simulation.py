"""End-to-end FL simulation (the paper's §4 experiment harness).

Drives FLServer + FLClients for T rounds over a non-IID partition, evaluating
the composed model M_COM(t) on the test set each ``eval_every`` rounds, and
tracking the train-vs-test accuracy gap (the paper's Fig. 2 overfitting
evidence) plus communication bytes with/without selection (the efficiency
claim). With ``cfg.distributed_selection`` the cohort's client side runs
through the pod-scale stacked engine (``repro.core.distributed``) instead of
the per-client Python loop — same math, optionally sharded over ``mesh``.

Fault tolerance: pass ``fault_plan`` (a ``repro.fl.faults.FaultPlan``) and
every frame crosses a ``FaultyChannel`` instead of the perfect wire —
clients crash, frames corrupt/truncate/duplicate, detected corruption is
retransmitted (bounded, charged under the ledger's ``retransmit``
category), and the server aggregates over exactly the clients whose
update frames decoded (the arrival mask; Eq. 2 renormalizes). Clients
failing ``quarantine_after`` consecutive rounds sit out
``quarantine_cooldown`` rounds. With no plan (or an all-zero one) the
round math, sampling streams and ledger are bit-identical to the
fault-free simulator."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.obs.timing import monotonic
from repro.core.compose import evaluate
from repro.core.rounds import run_cohort
from repro.core.split import SplitModel
from repro.data.datasets import Dataset
from repro.data.partition import ClientData
from repro.fl.client import FLClient
from repro.fl.comms import CommLedger
from repro.fl.server import FLServer
from repro.fl.transport.channel import Channel


@dataclass
class SimulationResult:
    test_acc: List[float] = field(default_factory=list)      # M_COM(t) accuracy
    fedavg_acc: List[float] = field(default_factory=list)    # plain W_G(t) accuracy
    meta_train_acc: List[float] = field(default_factory=list)  # on D_M (overfit probe)
    metadata_counts: List[int] = field(default_factory=list)
    cohort_samples: List[int] = field(default_factory=list)  # sum_k |D_k| per round
    client_loss: List[float] = field(default_factory=list)
    straggler_counts: List[int] = field(default_factory=list)  # dropped per round
    comm: dict = field(default_factory=dict)
    wall_time: float = 0.0
    # --- fault-tolerance counters (all-zero on the perfect wire) ---
    drops: List[int] = field(default_factory=list)             # updates lost/round
    corruptions_detected: List[int] = field(default_factory=list)
    retransmits: List[int] = field(default_factory=list)
    quarantined: List[int] = field(default_factory=list)       # held out/round
    # --- observability (populated only when cfg.observability; else None,
    # so BENCH JSONs stop re-deriving round timing ad hoc) ---
    round_wall_s: Optional[List[float]] = None                 # per-round wall
    phase_wall_s: Optional[List[Dict[str, float]]] = None      # per-round
    #   {phase name -> seconds} from the round span's direct children
    #   (broadcast / cohort / aggregate / eval)

    @property
    def selected_fraction(self) -> float:
        """The paper's headline |D_M|/|D_k|, for the LAST round: selected
        metadata over the samples of the clients that actually participated.
        (Dividing by ALL clients' samples understated the fraction whenever
        clients_per_round < num_clients.)"""
        if not self.metadata_counts:
            return 0.0
        denom = (self.cohort_samples[-1] if self.cohort_samples
                 else self.comm.get("total_samples", 1))
        return self.metadata_counts[-1] / max(denom, 1)


class FLSimulation:
    def __init__(self, model: SplitModel, clients: List[ClientData],
                 test: Dataset, cfg: FLConfig, seed: int = 0,
                 client_speeds: Optional[np.ndarray] = None,
                 mesh=None, deadline: Optional[float] = None,
                 flops_per_sample: float = 1e9,
                 fault_plan=None, fault_seed: int = 0,
                 quarantine_after: int = 0, quarantine_cooldown: int = 5,
                 tracer=None):
        self.model, self.cfg, self.test = model, cfg, test
        self.mesh = mesh                 # 'data'-axis mesh for sharded selection
        key = jax.random.PRNGKey(seed)
        k_init, self.key = jax.random.split(key)
        params = model.init(k_init)
        _, upper0 = model.split(params)
        # deadline: the ROADMAP straggler policy — clients whose estimated
        # local time (FLClient.local_time under flops_per_sample) exceeds
        # it are masked out of WeightAverage instead of waited for
        self.server = FLServer(model, params, upper0, cfg, deadline=deadline,
                               quarantine_after=quarantine_after,
                               quarantine_cooldown=quarantine_cooldown)
        # observability: with the knob on the simulation owns a Tracer and
        # the ledger is swapped for the metered twin BEFORE the channel is
        # built, so every wire charge attributes to the span that made it.
        # Off (the default) the NullTracer leaves the plain CommLedger in
        # place — byte- and bit-identical to the uninstrumented runtime.
        if tracer is None:
            tracer = (obs.Tracer(meta={"seed": seed,
                                       "num_clients": len(clients)})
                      if cfg.observability else obs.NULL_TRACER)
        self.tracer = tracer
        if self.tracer.enabled:
            self.server.ledger = obs.MeteredLedger(self.tracer)
        # the wire every frame crosses: perfect, or fault-injecting under a
        # FaultPlan (its own seed, so fault schedules and FL randomness are
        # independent streams)
        if fault_plan is not None and fault_plan.any_faults:
            from repro.fl.faults import FaultyChannel
            self.channel = FaultyChannel(self.server.ledger, fault_plan,
                                         seed=fault_seed,
                                         checksum=cfg.transport_checksum)
        else:
            self.channel = Channel(self.server.ledger,
                                   checksum=cfg.transport_checksum)
        self.flops_per_sample = flops_per_sample
        speeds = client_speeds if client_speeds is not None else np.ones(len(clients))
        self.clients = [FLClient(c, s) for c, s in zip(clients, speeds)]
        self.num_classes = test.num_classes

    def _cohort_round(self, cohort: List[FLClient], keys: jax.Array,
                      client_ids=None):
        """Client side of one round -> (params, metadatas, losses) lists.
        ``rounds.run_cohort`` owns the engine dispatch: the stacked pod
        engine when configured (and the cohort stacks within budget), else
        the per-client loop with batched-selection precompute."""
        return run_cohort(
            self.model, self.server.global_params,
            [c.client for c in cohort], self.cfg, keys,
            self.server.ledger, self.num_classes, mesh=self.mesh,
            channel=self.channel, client_ids=client_ids)

    def run(self, rounds: int, eval_every: int = 1,
            verbose: bool = False) -> SimulationResult:
        res = SimulationResult()
        tracer = self.tracer
        if tracer.enabled:
            res.round_wall_s, res.phase_wall_s = [], []
        t0 = monotonic()
        total_samples = sum(len(c.client.data) for c in self.clients)
        with obs.use_tracer(tracer):
            for t in range(rounds):
                with obs.span("round", round=t) as rsp:
                    self._run_round(t, rounds, eval_every, verbose, res, rsp)
                if tracer.enabled:
                    res.round_wall_s.append(rsp.duration)
                    res.phase_wall_s.append(tracer.child_durations(rsp))
        res.comm = self.server.ledger.summary()
        res.comm["total_samples"] = total_samples
        res.wall_time = monotonic() - t0
        return res

    def _run_round(self, t: int, rounds: int, eval_every: int,
                   verbose: bool, res: SimulationResult, rsp) -> None:
        self.key, k_round, k_sample = jax.random.split(self.key, 3)
        n_quar = self.server.num_quarantined(len(self.clients))
        res.quarantined.append(n_quar)
        obs.gauge("fl.quarantined", n_quar)
        self.channel.begin_round(t)
        idx = self.server.sample_clients(len(self.clients), k_sample)
        # per-client keys keep the seed's streams (split count changes
        # every key, so the count must stay len(idx)); the aggregate
        # key is derived separately — it used to alias the last
        # client's key
        keys = jax.random.split(k_round, len(idx))
        # flcheck: disable=RNG001 (deliberate: the server key must be derived from k_round without changing the historical split count; fold_in(k_round, len(idx)) is disjoint from every split stream)
        k_server = jax.random.fold_in(k_round, len(idx))
        cohort = [self.clients[int(i)] for i in idx]
        # the formed cohort downloads W_G(t-1) NOW (round 0 included)
        with obs.span("broadcast", clients=len(cohort)):
            self.server.broadcast_weights(len(cohort), channel=self.channel)
        with obs.span("cohort", clients=len(cohort)) as csp:
            cparams, metas, losses = self._cohort_round(
                cohort, keys, client_ids=[int(i) for i in idx])
            csp.sync(cparams)
        # arrival mask: which UpperUpdate frames actually decoded (the
        # perfect wire says all); where a corrupted frame was silently
        # accepted (checksums off) the server must consume ITS decode,
        # not the client's in-memory params
        arrived = np.asarray(
            [self.channel.update_arrived(int(i)) for i in idx])
        for j, i in enumerate(idx):
            dec = self.channel.decoded_update(int(i))
            if dec is not None:
                cparams[j] = dec
        tracer_on = self.tracer.enabled
        # deadline policy: estimated local times decide who the server
        # stops waiting for (mask=None -> exact unweighted Eq. 2)
        mask = self.server.straggler_mask(
            [c.local_time(self.cfg, self.flops_per_sample)
             for c in cohort])
        n_late = 0 if mask is None else int(mask.sum())
        res.straggler_counts.append(n_late)
        obs.gauge("fl.stragglers", n_late)
        rr = self.server.aggregate(cparams, metas, k_server,
                                   stragglers=mask, arrived=arrived)
        self.server.record_arrivals([int(i) for i in idx], arrived)
        stats = self.channel.round_stats()
        res.drops.append(int((~arrived).sum()))
        res.corruptions_detected.append(stats["corruptions_detected"])
        res.retransmits.append(stats["retransmits"])
        res.client_loss.append(float(np.mean(losses)))
        res.metadata_counts.append(rr.metadata_count)
        res.cohort_samples.append(
            sum(len(c.client.data) for c in cohort))
        if tracer_on:
            rsp.set(clients=len(cohort), drops=res.drops[-1],
                    stragglers=n_late, quarantined=n_quar,
                    metadata_count=rr.metadata_count)
        if (t + 1) % eval_every == 0 or t == rounds - 1:
            with obs.span("eval"):
                acc = evaluate(self.model, rr.composed_params,
                               self.test.x, self.test.y)
                fa_acc = evaluate(self.model, rr.global_params,
                                  self.test.x, self.test.y)
            res.test_acc.append(acc)
            res.fedavg_acc.append(fa_acc)
            if verbose:
                print(f"round {t+1:4d}  M_COM acc={acc:.4f}  "
                      f"FedAvg acc={fa_acc:.4f}  |D_M|={rr.metadata_count}")
