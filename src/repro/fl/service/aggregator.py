"""FedBuff-style buffered aggregation with staleness-weighted WeightAverage.

The synchronous server drops late clients (``FLServer.straggler_mask``:
weight 0 past the deadline). The async service generalizes that hard cutoff
into a CONTINUOUS weight: every buffered update carries the model version
it downloaded, and at flush time its Eq. 2 weight decays polynomially in
the version lag,

    w(s) = (1 + s) ** -alpha,     s = flush_version - download_version,

the FedBuff staleness discount (alpha=0.5 default). A fresh update (s=0)
keeps weight 1; the deadline policy is the alpha -> infinity limit. Weights
compose with the transport arrival mask (a lost frame is weight 0 whatever
its age), and ``fedavg.weight_average`` renormalizes, so the flush is still
Eq. 2 over the updates that count.

Bit-identity contract: when every buffered update is fresh (all staleness
zero) the flush passes ``fedavg_weights=None`` and lets
``FLServer.aggregate`` derive weights from the arrival mask alone — the
EXACT code path the synchronous simulator takes — so the degenerate service
(buffer == cohort, zero delay) reproduces ``FLSimulation`` byte-for-byte.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, NamedTuple, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.rounds import RoundResult
from repro.fl.server import FLServer

PyTree = Any


class BufferEntry(NamedTuple):
    """One client upload waiting in the server's buffer.

    ``version`` is ``server.round_idx`` at the moment the client downloaded
    the weights it trained on; ``tick`` is the arrival tick (queue-wait
    telemetry). ``arrived``/``metadata`` are captured at upload time —
    channel round state is per-tick, so the flush must not re-ask the wire.
    """
    client_id: int
    params: PyTree
    metadata: Optional[tuple]
    version: int
    arrived: bool
    tick: int


def staleness_weight(staleness: int, alpha: float = 0.5) -> float:
    """The FedBuff polynomial discount ``(1 + s) ** -alpha``. s=0 -> 1.0;
    alpha=0 recovers the unweighted mean; larger alpha forgets stale
    updates faster (the deadline policy is the limit)."""
    if staleness < 0:
        raise ValueError(f"negative staleness {staleness}")
    return float((1.0 + staleness) ** (-alpha))


@dataclass
class BufferedAggregator:
    """Accumulate uploads; flush a staleness-weighted WeightAverage through
    ``FLServer.aggregate`` once ``buffer_size`` updates are buffered.

    Every flush bumps ``server.round_idx`` — the model version — so
    staleness is measured in FLUSHES survived in the queue, not wall ticks.
    ``record_arrivals`` runs per flush with the flushed clients' arrival
    bits, so quarantine composes with buffering unchanged.
    """
    server: FLServer
    buffer_size: int
    staleness_alpha: float = 0.5
    entries: List[BufferEntry] = field(default_factory=list)
    flushes: int = 0
    # per-flush telemetry (mirrored into ServiceResult by the loop)
    last_staleness: List[int] = field(default_factory=list)

    def submit(self, entry: BufferEntry) -> bool:
        """Buffer one upload; True when the buffer is full (caller flushes
        with the tick's aggregate key — the key schedule lives in the loop,
        not here)."""
        self.entries.append(entry)
        return self.ready()

    def ready(self) -> bool:
        return len(self.entries) >= self.buffer_size

    def pending(self) -> int:
        return len(self.entries)

    def _weights(self, staleness: List[int],
                 arrived: np.ndarray) -> Optional[List[float]]:
        """Eq. 2 weights for one flush; None when every update is fresh,
        which routes ``FLServer.aggregate`` through the synchronous
        arrival-mask path (the bit-identity contract above)."""
        if not any(staleness):
            return None
        return [float(ok) * staleness_weight(s, self.staleness_alpha)
                for ok, s in zip(arrived, staleness)]

    def flush(self, key, tick: int) -> Tuple[RoundResult, List[int]]:
        """Drain the buffer through MetaTraining + staleness-weighted
        Eq. 2. ``key`` is the flush's aggregate (meta-training) key — the
        loop derives it from the tick's round key exactly as the simulator
        derives ``k_server``. Returns the RoundResult and the per-entry
        staleness (for the accuracy-vs-staleness telemetry)."""
        entries, self.entries = self.entries, []
        fv = self.server.round_idx
        staleness = [fv - e.version for e in entries]
        arrived = np.asarray([e.arrived for e in entries])
        weights = self._weights(staleness, arrived)
        with obs.span("service.buffer_flush", size=len(entries),
                      flush=self.flushes) as fsp:
            for e, s in zip(entries, staleness):
                obs.event("service.queue_wait", client=e.client_id,
                          wait_ticks=tick - e.tick, staleness=s)
            rr = self.server.aggregate(
                [e.params for e in entries],
                [e.metadata for e in entries], key,
                arrived=arrived, fedavg_weights=weights)
            self.server.record_arrivals(
                [e.client_id for e in entries], arrived)
            if fsp.enabled:
                fsp.set(max_staleness=max(staleness),
                        weighted=int(weights is not None))
        self.flushes += 1
        self.last_staleness = staleness
        return rr, staleness
