"""The event-driven FL service: arrivals in, staleness-weighted flushes out.

Where ``FLSimulation`` is a lock-step for-loop over rounds (form cohort,
wait for everyone, aggregate), :class:`FLService` runs the server as a
CONTINUOUS loop over ticks:

  tick t:  draw arrivals from the traffic model
           each arrival downloads W_G (one WeightBroadcast frame), runs the
             existing client pipeline (Extract&Selection + LocalUpdate) and
             uploads knowledge + update over the SAME transport channel the
             simulator uses (perfect or fault-injecting)
           uploads land in the buffered aggregator — immediately, or
             ``delay`` ticks later (training latency); once ``buffer_size``
             updates are buffered the flush runs MetaTraining + Eq. 2 with
             the FedBuff staleness discount and bumps the model version

Determinism and the sync oracle: each tick consumes the simulator's EXACT
key chain (``key, k_round, k_sample = split(key, 3)``; per-arrival keys
``split(k_round, n)``; flush keys from ``fold_in(k_round, n)``), arrivals
are pure functions of ``(traffic seed, tick)``, and faults stay keyed per
``(fault seed, tick, client)``. Under ``DegenerateTraffic`` with
``buffer_size == clients_per_round`` every stream, frame and flush aligns
with ``FLSimulation`` round-for-round — final weights and CommLedger are
bit-identical (asserted in tests/test_service.py and BENCH_service.json's
``async_degenerate_matches_sync`` claim).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import numpy as np

from repro import obs
from repro.configs.base import FLConfig
from repro.core.compose import evaluate
from repro.core.rounds import run_cohort
from repro.core.split import SplitModel
from repro.data.datasets import Dataset
from repro.data.partition import ClientData
from repro.fl.server import FLServer
from repro.fl.service.aggregator import BufferedAggregator, BufferEntry
from repro.fl.service.traffic import DegenerateTraffic, TrafficModel
from repro.fl.transport.channel import Channel
from repro.obs.timing import monotonic

PyTree = Any


@dataclass
class ServiceResult:
    """What a service run reports (the async twin of SimulationResult)."""
    test_acc: List[float] = field(default_factory=list)      # M_COM per eval
    fedavg_acc: List[float] = field(default_factory=list)    # W_G per eval
    client_loss: List[float] = field(default_factory=list)   # per arrival
    metadata_counts: List[int] = field(default_factory=list)  # per flush
    arrivals_per_tick: List[int] = field(default_factory=list)
    flush_sizes: List[int] = field(default_factory=list)
    flush_staleness: List[List[int]] = field(default_factory=list)
    # per-tick fault/quarantine counters (same meaning as SimulationResult)
    drops: List[int] = field(default_factory=list)
    corruptions_detected: List[int] = field(default_factory=list)
    retransmits: List[int] = field(default_factory=list)
    quarantined: List[int] = field(default_factory=list)
    comm: dict = field(default_factory=dict)
    ticks: int = 0
    flushes: int = 0
    wall_time: float = 0.0

    @property
    def mean_staleness(self) -> float:
        """Average version lag over every flushed update (0.0 in the
        degenerate/synchronous regime)."""
        flat = [s for fl in self.flush_staleness for s in fl]
        return float(np.mean(flat)) if flat else 0.0


class FLService:
    """A continuously running FL server over the wire format.

    Construction mirrors ``FLSimulation`` stream-for-stream (model init
    key, server, tracer, perfect-or-faulty channel) so the degenerate
    configuration is bit-identical by construction, not by luck. The
    differences are all post-cohort: arrivals come from ``traffic``,
    uploads queue in a ``BufferedAggregator`` (``buffer_size`` defaults to
    ``cfg.clients_per_round``), and Eq. 2 weights decay with staleness
    (``staleness_alpha``) instead of a deadline.
    """

    def __init__(self, model: SplitModel, clients: List[ClientData],
                 test: Dataset, cfg: FLConfig, seed: int = 0,
                 traffic: Optional[TrafficModel] = None,
                 buffer_size: Optional[int] = None,
                 staleness_alpha: float = 0.5,
                 mesh=None, fault_plan=None, fault_seed: int = 0,
                 quarantine_after: int = 0, quarantine_cooldown: int = 5,
                 tracer=None):
        self.model, self.cfg, self.test = model, cfg, test
        self.mesh = mesh
        key = jax.random.PRNGKey(seed)
        k_init, self.key = jax.random.split(key)
        params = model.init(k_init)
        _, upper0 = model.split(params)
        self.server = FLServer(model, params, upper0, cfg,
                               quarantine_after=quarantine_after,
                               quarantine_cooldown=quarantine_cooldown)
        if tracer is None:
            tracer = (obs.Tracer(meta={"seed": seed, "service": True,
                                       "num_clients": len(clients)})
                      if cfg.observability else obs.NULL_TRACER)
        self.tracer = tracer
        if self.tracer.enabled:
            self.server.ledger = obs.MeteredLedger(self.tracer)
        if fault_plan is not None and fault_plan.any_faults:
            from repro.fl.faults import FaultyChannel
            self.channel = FaultyChannel(self.server.ledger, fault_plan,
                                         seed=fault_seed,
                                         checksum=cfg.transport_checksum)
        else:
            self.channel = Channel(self.server.ledger,
                                   checksum=cfg.transport_checksum)
        self.traffic = traffic if traffic is not None else DegenerateTraffic()
        self.aggregator = BufferedAggregator(
            self.server,
            buffer_size=(buffer_size if buffer_size is not None
                         else cfg.clients_per_round),
            staleness_alpha=staleness_alpha)
        self.clients = list(clients)
        self.num_classes = test.num_classes
        # delayed uploads: (due_tick, enqueue_seq, BufferEntry) min-heap —
        # delivery order is (due time, upload order), never hash order
        self._pending: list = []
        self._seq = 0
        self._k_server = self.key          # replaced every tick
        self._flushes_this_tick = 0

    # ---- per-tick machinery ----
    def _client_pipeline(self, cid: int, key: jax.Array, tick: int
                         ) -> BufferEntry:
        """One arrival end to end: broadcast -> select/update -> upload.
        The entry captures the download version and the channel's verdict
        (arrival bit, server-side decode) at upload time — per-tick channel
        state must not be re-read at flush time."""
        version = self.server.round_idx
        with obs.span("broadcast", clients=1):
            self.server.broadcast_weights(1, channel=self.channel)
        with obs.span("cohort", clients=1) as csp:
            cparams, metas, losses = run_cohort(
                self.model, self.server.global_params,
                [self.clients[cid]], self.cfg, key[None],
                self.server.ledger, self.num_classes, mesh=self.mesh,
                channel=self.channel, client_ids=[cid])
            csp.sync(cparams)
        arrived = bool(self.channel.update_arrived(cid))
        dec = self.channel.decoded_update(cid)
        params = cparams[0] if dec is None else dec
        self._loss = float(np.mean(losses))
        return BufferEntry(client_id=cid, params=params, metadata=metas[0],
                           version=version, arrived=arrived, tick=tick)

    def _flush_key(self, k_server: jax.Array, flush_in_tick: int):
        """Flush f of a tick aggregates under ``k_server`` (f=0: the
        simulator's exact key) or a fold of it (f>0: extra flushes only
        exist in the async regime, so fresh derived streams are safe)."""
        if flush_in_tick == 0:
            return k_server
        return jax.random.fold_in(k_server, flush_in_tick)

    def _maybe_flush(self, k_server, tick: int, res: ServiceResult,
                     eval_every: int):
        while self.aggregator.ready():
            key = self._flush_key(k_server, self._flushes_this_tick)
            self._flushes_this_tick += 1
            rr, staleness = self.aggregator.flush(key, tick)
            self._last_rr = rr
            res.flushes += 1
            res.flush_sizes.append(len(staleness))
            res.flush_staleness.append(staleness)
            res.metadata_counts.append(rr.metadata_count)
            if res.flushes % eval_every == 0:
                self._eval(rr, res)
                self._evaled_last = True
            else:
                self._evaled_last = False

    def _eval(self, rr, res: ServiceResult) -> None:
        with obs.span("eval"):
            res.test_acc.append(evaluate(self.model, rr.composed_params,
                                         self.test.x, self.test.y))
            res.fedavg_acc.append(evaluate(self.model, rr.global_params,
                                           self.test.x, self.test.y))

    # ---- the loop ----
    def run(self, ticks: int, eval_every: int = 1,
            drain: bool = False) -> ServiceResult:
        """Run the service for ``ticks`` ticks. ``eval_every`` evaluates
        M_COM/W_G every that many FLUSHES (the final flush is always
        evaluated); ``drain`` force-flushes a partial buffer after the last
        tick so short runs still aggregate."""
        res = ServiceResult()
        self._last_rr = None
        self._evaled_last = True
        t0 = monotonic()
        with obs.use_tracer(self.tracer):
            for t in range(ticks):
                with obs.span("service.tick", tick=t) as tsp:
                    self._run_tick(t, res, eval_every, tsp)
            if drain and self.aggregator.pending():
                key = self._flush_key(self._k_server,
                                      self._flushes_this_tick)
                rr, staleness = self.aggregator.flush(key, ticks - 1)
                self._last_rr = rr
                res.flushes += 1
                res.flush_sizes.append(len(staleness))
                res.flush_staleness.append(staleness)
                res.metadata_counts.append(rr.metadata_count)
                self._evaled_last = False
            if self._last_rr is not None and not self._evaled_last:
                self._eval(self._last_rr, res)
        res.ticks = ticks
        res.comm = self.server.ledger.summary()
        res.wall_time = monotonic() - t0
        return res

    def _run_tick(self, t: int, res: ServiceResult, eval_every: int,
                  tsp) -> None:
        # the simulator's exact per-round key chain (simulation.py keeps
        # the same shape; the degenerate service must consume identical
        # streams)
        self.key, k_round, k_sample = jax.random.split(self.key, 3)
        n_quar = self.server.num_quarantined(len(self.clients))
        res.quarantined.append(n_quar)
        obs.gauge("fl.quarantined", n_quar)
        self.channel.begin_round(t)
        arrivals = self.traffic.arrivals(t, self.server, len(self.clients),
                                         k_sample)
        idx = [a.client_id for a in arrivals]
        keys = jax.random.split(k_round, len(idx)) if idx else None
        # flcheck: disable=RNG001 (deliberate: flush keys must derive from k_round without changing the historical split count; fold_in(k_round, len(idx)) matches the simulator's k_server stream exactly)
        self._k_server = jax.random.fold_in(k_round, len(idx))
        self._flushes_this_tick = 0
        # deliveries due this tick (uploads from earlier, slower arrivals)
        while self._pending and self._pending[0][0] <= t:
            _, _, entry = heapq.heappop(self._pending)
            self.aggregator.submit(entry)
            self._maybe_flush(self._k_server, t, res, eval_every)
        n_drop = 0
        for j, a in enumerate(arrivals):
            entry = self._client_pipeline(a.client_id, keys[j], t)
            res.client_loss.append(self._loss)
            n_drop += int(not entry.arrived)
            if a.delay > 0:
                obs.event("service.upload_deferred", client=a.client_id,
                          due=t + a.delay)
                heapq.heappush(self._pending,
                               (t + a.delay, self._seq, entry))
                self._seq += 1
            else:
                self.aggregator.submit(entry)
                self._maybe_flush(self._k_server, t, res, eval_every)
        stats = self.channel.round_stats()
        res.arrivals_per_tick.append(len(arrivals))
        res.drops.append(n_drop)
        res.corruptions_detected.append(stats["corruptions_detected"])
        res.retransmits.append(stats["retransmits"])
        if tsp.enabled:
            tsp.set(arrivals=len(arrivals), drops=n_drop,
                    quarantined=n_quar, buffered=self.aggregator.pending(),
                    flushes=self._flushes_this_tick)
