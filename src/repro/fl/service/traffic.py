"""Deterministic, seeded client-arrival models for the async FL service.

The event-driven server (``repro.fl.service.loop``) does not form cohorts —
clients ARRIVE, drawn per tick from one of these traffic models. Each model
is a pure function of ``(seed, tick)``: like ``repro.fl.faults.FaultPlan``,
every random decision comes from a ``np.random.SeedSequence`` stream keyed
on the tick, never from call order, so replaying a service run (or resuming
it mid-stream) reproduces the identical arrival schedule.

Three profiles:

  DegenerateTraffic  the sync-equivalence anchor: tick t's arrivals are
                     EXACTLY the cohort the sequential simulator would have
                     sampled (``FLServer.sample_clients`` on the same jax
                     key), all with zero upload delay — the configuration
                     under which the service must reproduce ``FLSimulation``
                     bit-for-bit (weights and ledger).
  PoissonTraffic     homogeneous load: arrivals-per-tick ~ Poisson(rate),
                     clients uniform over the server's ELIGIBLE set (so
                     quarantine composes), optional uniform upload delays.
  DiurnalTraffic     Poisson with a sinusoidal day/night rate profile —
                     the "heavy traffic from millions of users" shape where
                     staleness actually accrues.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, NamedTuple

import numpy as np

# stream ids for the per-tick SeedSequence (call-order independence, same
# convention as repro.fl.faults)
_STREAM_ARRIVALS = 0


class Arrival(NamedTuple):
    """One client hitting the service this tick.

    ``delay`` is the number of ticks between the client's model download
    (it trains on what it downloaded NOW) and its upload landing in the
    server's buffer — the latency that turns into staleness when other
    flushes bump the model version in between. Zero means the upload is
    buffered within the arrival tick.
    """
    client_id: int
    delay: int = 0


class TrafficModel:
    """Interface: ``arrivals(tick, server, num_clients, key)`` -> arrival
    list for that tick. ``server`` exposes the quarantine view
    (``eligible_clients``) and, for the degenerate model, the historical
    cohort sampler; ``key`` is the tick's jax sampling key (used only by
    :class:`DegenerateTraffic` — the stochastic models draw from their own
    numpy streams so their schedules are independent of FL randomness)."""

    def arrivals(self, tick: int, server, num_clients: int,
                 key) -> List[Arrival]:
        raise NotImplementedError


@dataclass(frozen=True)
class DegenerateTraffic(TrafficModel):
    """The synchronous simulator's cohort, replayed as an arrival burst.

    Tick t yields exactly ``server.sample_clients(num_clients, key)`` —
    the same jax draw, in the same order, with zero delay — so a service
    driven by this model consumes the identical RNG streams as
    ``FLSimulation`` round t. With ``buffer_size == clients_per_round``
    this is the bit-identity configuration (see tests/test_service.py).
    """

    def arrivals(self, tick: int, server, num_clients: int,
                 key) -> List[Arrival]:
        idx = server.sample_clients(num_clients, key)
        return [Arrival(int(i), 0) for i in idx]


@dataclass(frozen=True)
class PoissonTraffic(TrafficModel):
    """Homogeneous Poisson arrivals.

    Per tick: ``n ~ Poisson(rate)`` arrivals, each an independent uniform
    draw over the server's eligible clients (WITH replacement — a busy
    client can check in twice a tick), each with a uniform upload delay in
    ``[0, delay_ticks]``. All draws come from the
    ``SeedSequence((seed, tick, stream))`` generator, so the schedule is a
    pure function of ``(seed, tick)``.
    """
    rate: float = 2.0
    seed: int = 0
    delay_ticks: int = 0

    def _rng(self, tick: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence(
            (int(self.seed), int(tick), _STREAM_ARRIVALS)))

    def rate_at(self, tick: int) -> float:
        """Expected arrivals at ``tick`` (constant here; diurnal bends it)."""
        return self.rate

    def arrivals(self, tick: int, server, num_clients: int,
                 key) -> List[Arrival]:
        rng = self._rng(tick)
        n = int(rng.poisson(max(self.rate_at(tick), 0.0)))
        if n == 0:
            return []
        elig = server.eligible_clients(num_clients)
        if not elig:
            elig = list(range(num_clients))
        pos = rng.integers(0, len(elig), size=n)
        delays = (rng.integers(0, self.delay_ticks + 1, size=n)
                  if self.delay_ticks > 0 else np.zeros(n, np.int64))
        return [Arrival(int(elig[p]), int(d)) for p, d in zip(pos, delays)]


@dataclass(frozen=True)
class DiurnalTraffic(PoissonTraffic):
    """Poisson arrivals under a sinusoidal day/night load profile:
    ``rate(t) = base_rate * (1 + amplitude * sin(2*pi*t / period))``,
    floored at zero. ``amplitude=1`` swings between 0 and 2x the base rate
    over one ``period`` of ticks; staleness accrues in the trough, where
    uploads outlive the flushes that age them."""
    amplitude: float = 0.8
    period: int = 24

    def rate_at(self, tick: int) -> float:
        phase = 2.0 * np.pi * (tick % self.period) / max(self.period, 1)
        return max(self.rate * (1.0 + self.amplitude * float(np.sin(phase))),
                   0.0)
