"""repro.fl.service — the event-driven FL server (ROADMAP item 1).

``FLSimulation`` is a synchronous for-loop over rounds; this package runs
the same split-FL math as a continuously ticking service: seeded traffic
models produce client arrivals (``traffic``), each arrival replays the
existing client pipeline over the wire format, and a FedBuff-style buffered
aggregator (``aggregator``) applies staleness-weighted WeightAverage once
``buffer_size`` updates accumulate. The synchronous simulator remains the
bit-exact oracle for the degenerate configuration — see
``docs/architecture.md`` ("Bit-identity contracts") and tests/test_service.py.
"""
from repro.fl.service.aggregator import (BufferedAggregator, BufferEntry,
                                         staleness_weight)
from repro.fl.service.loop import FLService, ServiceResult
from repro.fl.service.traffic import (Arrival, DegenerateTraffic,
                                      DiurnalTraffic, PoissonTraffic,
                                      TrafficModel)

__all__ = [
    "Arrival", "BufferEntry", "BufferedAggregator", "DegenerateTraffic",
    "DiurnalTraffic", "FLService", "PoissonTraffic", "ServiceResult",
    "TrafficModel", "staleness_weight",
]
