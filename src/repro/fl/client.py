"""Client role: owns local data, executes Extract&Selection + LocalUpdate.

The simulator drives many FLClient objects in-process; the pod runtime maps
cohorts of clients onto mesh shards instead (repro.core.distributed). A
simple cost model estimates local wall-time so straggler behaviour (the
paper's motivation) can be simulated and reported — ``local_time`` is what
``FLServer.straggler_mask`` compares against ``FLServer.deadline`` to drop
stragglers from WeightAverage instead of waiting. Uploads are charged by
``repro.fl.transport`` at exact encoded-frame bytes."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax

from repro.configs.base import FLConfig
from repro.core.rounds import client_round
from repro.core.split import SplitModel
from repro.data.partition import ClientData
from repro.fl.comms import CommLedger


@dataclass
class FLClient:
    client: ClientData
    compute_speed: float = 1.0       # relative FLOP/s (heterogeneous hardware)

    def local_time(self, cfg: FLConfig, flops_per_sample: float) -> float:
        """Estimated local round time: epochs * |D_k| * flops / speed.
        Selection adds one lower-forward over |D_k| (still ~3x cheaper than a
        training epoch) — the quantity the paper reduces."""
        n = len(self.client.data)
        train = cfg.local_epochs * n * 3 * flops_per_sample
        select = n * flops_per_sample if cfg.use_selection else 0
        return (train + select) / (self.compute_speed * 1e9)

    def run(self, model: SplitModel, params: Any, cfg: FLConfig,
            key: jax.Array, ledger: CommLedger, num_classes: int,
            precomputed=None, channel=None, client_id: int = 0):
        return client_round(model, params, self.client, cfg, key, ledger,
                            num_classes, precomputed=precomputed,
                            channel=channel, client_id=client_id)
