"""Typed wire messages: the three payloads Algorithm 1 actually exchanges.

Every exchange in the split-FL round is one of:

  WeightBroadcast    server -> client   W_G(t-1), one frame per cohort member
  SelectedKnowledge  client -> server   the Extract&Selection output — the
                                        selected activation maps + labels +
                                        per-slot validity (the paper's
                                        metadata D_M_k, the payload its
                                        ~1.6% claim is about)
  UpperUpdate        client -> server   the client's updated weights for
                                        WeightAverage (Eq. 2)

Each message has an ``encode() -> bytes`` / ``decode(wire)`` round-trip
contract, and the CommLedger is charged ``len(encode())`` — the byte-true
replacement for the old ``size * 4`` estimates (which miscounted every
non-f32 payload and ignored framing entirely).

Frame layout (little-endian), wire VERSION 2:

  0   4  magic  b"FLTP"
  4   1  version (2; version-1 frames still decode — no flags, no trailer)
  5   1  msg type
  6   1  codec wire id (knowledge frames; 0 for weight frames)
  7   1  flags (bit 0 = CRC32 trailer present; v1's reserved byte)
  8   4  payload length (trailer NOT included)
  12  …  payload
  +4     CRC32 of header+payload, only when flags bit 0 is set

The CRC covers the header too, so a bit-flip anywhere in the frame —
length field included — is caught; decode raises the typed ``FrameError``
hierarchy (``transport.errors``) instead of leaking ``struct.error`` /
``IndexError`` / numpy ``ValueError`` on mangled input, so the fault
runtime can tell retriable corruption from protocol bugs.

Weight payloads are a leaf count followed by array blocks
(dtype u8 | ndim u8 | dims u32* | raw bytes) in tree-flatten order — the
model ARCHITECTURE is common knowledge between server and clients, so only
numbers cross the wire and ``unflatten_like`` restores the pytree.

Knowledge payloads carry the VALID slots only: slot count, valid count, the
per-map shape, a packed validity bitmap, the labels, the codec's parameter
block, then the codec-encoded rows. Empty-cluster slots cost one BIT each,
and a client whose selection came back all-invalid sends a 23-byte frame
instead of a full metadata tensor.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.transport.codecs import (Quantized, TensorCodec, codec_by_code,
                                       get_codec)
from repro.fl.transport.errors import (BadMagic, BadVersion, ChecksumMismatch,
                                       FrameError, LengthMismatch,
                                       TruncatedFrame, UnknownDtype,
                                       WrongMessageType)

MAGIC = b"FLTP"
VERSION = 2
V1 = 1                                         # still decoded (compat)
FLAG_CHECKSUM = 0x01                           # flags bit 0: CRC32 trailer
_KNOWN_FLAGS = FLAG_CHECKSUM
CRC_BYTES = 4

MSG_WEIGHT_BROADCAST = 1
MSG_SELECTED_KNOWLEDGE = 2
MSG_UPPER_UPDATE = 3

_HEADER = struct.Struct("<4sBBBBI")
HEADER_BYTES = _HEADER.size                    # 12

_DTYPES: List[np.dtype] = [
    np.dtype(np.float32), np.dtype(np.float16), np.dtype(jnp.bfloat16),
    np.dtype(np.int8), np.dtype(np.uint8), np.dtype(np.int32),
    np.dtype(np.int64), np.dtype(np.uint32), np.dtype(np.bool_),
]
_DTYPE_CODE = {dt: i for i, dt in enumerate(_DTYPES)}


def _dtype_code(dt) -> int:
    dt = np.dtype(dt)
    if dt not in _DTYPE_CODE:
        raise ValueError(f"no wire code for dtype {dt}")
    return _DTYPE_CODE[dt]


def _pack_header(msg_type: int, codec_code: int, payload: bytes,
                 checksum: bool = False) -> bytes:
    flags = FLAG_CHECKSUM if checksum else 0
    frame = _HEADER.pack(MAGIC, VERSION, msg_type, codec_code, flags,
                         len(payload)) + payload
    if checksum:
        frame += struct.pack("<I", zlib.crc32(frame) & 0xFFFFFFFF)
    return frame


def _unpack_header(wire: bytes) -> Tuple[int, int, bytes]:
    """Parse + validate a frame down to its payload. Raises the typed
    ``FrameError``s (never ``struct.error``): a sub-header buffer is
    ``TruncatedFrame``, a wrong total length splits into truncation vs.
    trailing garbage, and when the v2 checksum flag is set the CRC32
    trailer is verified over header+payload — so a flip ANYWHERE in the
    frame (length field included: a corrupt length either fails the total
    length check or feeds wrong bytes to the CRC) is caught."""
    if len(wire) < HEADER_BYTES:
        raise TruncatedFrame(
            f"frame shorter than the {HEADER_BYTES}-byte header: {len(wire)}")
    magic, ver, msg_type, codec_code, flags, plen = _HEADER.unpack_from(
        wire, 0)
    if magic != MAGIC:
        raise BadMagic(f"bad frame magic {magic!r}")
    if ver == V1:
        flags = 0                    # v1's reserved byte carries no meaning
    elif ver == VERSION:
        if flags & ~_KNOWN_FLAGS:
            raise BadVersion(f"unknown v{ver} flag bits 0x{flags:02x}")
    else:
        raise BadVersion(f"unsupported frame version {ver}")
    crc = bool(flags & FLAG_CHECKSUM)
    expect = HEADER_BYTES + plen + (CRC_BYTES if crc else 0)
    if len(wire) < expect:
        raise TruncatedFrame(f"frame length {len(wire)} < expected {expect}")
    if len(wire) != expect:
        raise LengthMismatch(
            f"frame length {len(wire)} != expected {expect}")
    if crc:
        (got,) = struct.unpack_from("<I", wire, HEADER_BYTES + plen)
        want = zlib.crc32(wire[:HEADER_BYTES + plen]) & 0xFFFFFFFF
        if got != want:
            raise ChecksumMismatch(
                f"frame CRC32 0x{got:08x} != computed 0x{want:08x}")
    return msg_type, codec_code, wire[HEADER_BYTES:HEADER_BYTES + plen]


def _need(buf: bytes, off: int, n: int, what: str) -> None:
    if off + n > len(buf):
        raise TruncatedFrame(
            f"payload ends inside {what}: need {n} bytes at offset {off}, "
            f"have {len(buf) - off}")


def _pack_array(a: np.ndarray) -> bytes:
    # tobytes() is C-order regardless of layout; no ascontiguousarray —
    # it would promote 0-d leaves to (1,) and break their round-trip
    head = struct.pack("<BB", _dtype_code(a.dtype), a.ndim)
    dims = struct.pack(f"<{a.ndim}I", *a.shape) if a.ndim else b""
    return head + dims + a.tobytes()


def _unpack_array(buf: bytes, off: int) -> Tuple[np.ndarray, int]:
    _need(buf, off, 2, "array block head")
    code, ndim = struct.unpack_from("<BB", buf, off)
    off += 2
    if code >= len(_DTYPES):
        raise UnknownDtype(f"array dtype code {code} outside the wire table "
                           f"(0..{len(_DTYPES) - 1})")
    _need(buf, off, 4 * ndim, "array dims")
    shape = struct.unpack_from(f"<{ndim}I", buf, off) if ndim else ()
    off += 4 * ndim
    dt = _DTYPES[code]
    n = 1                            # Python ints: corrupt dims can't overflow
    for s in shape:
        n *= int(s)
    _need(buf, off, n * dt.itemsize, "array data")
    a = np.frombuffer(buf, dt, count=n, offset=off).reshape(shape).copy()
    return a, off + n * dt.itemsize


def _encode_pytree(msg_type: int, tree: Any, checksum: bool = False) -> bytes:
    leaves = [np.asarray(x) for x in jax.tree.leaves(tree)]
    payload = struct.pack("<I", len(leaves)) + b"".join(
        _pack_array(a) for a in leaves)
    return _pack_header(msg_type, 0, payload, checksum=checksum)


def _decode_pytree(wire: bytes, expect_type: int) -> List[np.ndarray]:
    msg_type, _, payload = _unpack_header(wire)
    if msg_type != expect_type:
        raise WrongMessageType(
            f"expected msg type {expect_type}, got {msg_type}")
    _need(payload, 0, 4, "leaf count")
    (n,) = struct.unpack_from("<I", payload, 0)
    off, leaves = 4, []
    for _ in range(n):
        a, off = _unpack_array(payload, off)
        leaves.append(a)
    if off != len(payload):
        raise LengthMismatch(
            f"{len(payload) - off} trailing bytes after the last leaf")
    return leaves


def pytree_frame_nbytes(tree: Any, checksum: bool = False) -> int:
    """Exact byte length of the WeightBroadcast/UpperUpdate frame for
    ``tree`` WITHOUT serializing it: the frame is a pure function of leaf
    shapes/dtypes (header + leaf count + per-leaf dtype/ndim/dims head +
    raw bytes + the 4-byte CRC trailer when ``checksum``), so ledger
    charging needs no device->host copy of the weights. Kept equal to
    ``len(_encode_pytree(...))`` by construction (asserted in
    tests/test_transport.py)."""
    total = HEADER_BYTES + 4 + (CRC_BYTES if checksum else 0)
    for a in jax.tree.leaves(tree):
        if not hasattr(a, "ndim") or not hasattr(a, "dtype"):
            a = np.asarray(a)
        _dtype_code(a.dtype)             # same unknown-dtype error as encode
        total += 2 + 4 * a.ndim + int(a.size) * np.dtype(a.dtype).itemsize
    return total


def unflatten_like(tree: Any, leaves: List[np.ndarray]) -> Any:
    """Rebuild a decoded weight payload into ``tree``'s structure (the
    architecture is shared out-of-band; the wire carries numbers only)."""
    return jax.tree.unflatten(jax.tree.structure(tree),
                              [jnp.asarray(a) for a in leaves])


@dataclass
class WeightBroadcast:
    """server -> client: the global model W_G(t-1) the cohort trains from."""
    params: Any

    MSG_TYPE = MSG_WEIGHT_BROADCAST

    def encode(self, checksum: bool = False) -> bytes:
        """Pytree -> wire frame: header + per-leaf (dtype code, ndim,
        dims, native-dtype bytes); ``checksum`` appends the v2 CRC32
        trailer."""
        return _encode_pytree(self.MSG_TYPE, self.params, checksum=checksum)

    @classmethod
    def decode(cls, wire: bytes) -> List[np.ndarray]:
        """Wire frame -> leaf list in encode order (structure is shared
        out-of-band; see ``unflatten_like``). Raises transport errors, not
        struct/numpy ones, on any malformed byte."""
        return _decode_pytree(wire, cls.MSG_TYPE)


@dataclass
class UpperUpdate:
    """client -> server: the locally-updated weights entering Eq. 2.
    (On the split network the lower part is what FedAvg really shares; the
    simulator ships the client's full updated tree, and this frame charges
    exactly those bytes.)"""
    params: Any

    MSG_TYPE = MSG_UPPER_UPDATE

    def encode(self, checksum: bool = False) -> bytes:
        """Same pytree wire layout as ``WeightBroadcast.encode``, under
        the UpperUpdate message type byte."""
        return _encode_pytree(self.MSG_TYPE, self.params, checksum=checksum)

    @classmethod
    def decode(cls, wire: bytes) -> List[np.ndarray]:
        """Wire frame -> leaf list (encode order); transport errors only
        on malformed bytes, mirroring ``WeightBroadcast.decode``."""
        return _decode_pytree(wire, cls.MSG_TYPE)


@dataclass
class SelectedKnowledge:
    """client -> server: the §3.1 selection output. ``acts`` is the fixed
    ``num_classes*clusters_per_class``-slot tensor, ``valid`` marks the
    non-empty-cluster slots; only valid rows are encoded. ``pre`` is an
    optional pre-quantized payload from the batched cohort encoder (the
    per-client quantize is then skipped — same bytes either way)."""
    acts: Any                                  # (CK, *map_shape)
    labels: Any                                # (CK,) int
    valid: Any                                 # (CK,) bool
    codec: TensorCodec = field(default_factory=lambda: get_codec("raw_f32"))
    pre: Optional[Quantized] = None

    MSG_TYPE = MSG_SELECTED_KNOWLEDGE

    def encode(self, checksum: bool = False) -> bytes:
        """Selection triple -> wire frame. Body layout after the common
        header: ``<IIB`` (CK, nvalid, ndim of the map shape), the map dims
        as ``<I`` each, one label-dtype code byte, the packed valid
        bitmask, ``<H``-length-prefixed codec params, the valid labels,
        then the codec's row payload. Only valid rows cross the wire."""
        labels = np.asarray(self.labels)
        valid = np.asarray(self.valid).astype(bool)
        shape = tuple(self.acts.shape)
        ck, map_shape = shape[0], shape[1:]
        # with a pre-quantized payload the codec never reads the floats —
        # don't device->host copy the full fixed-slot tensor just to
        # discard it (shape/labels/valid are all the framing needs)
        flat = (None if self.pre is not None
                else np.asarray(self.acts).reshape(ck, -1).astype(np.float32))
        payload_rows, params = self.codec.encode(flat, valid, pre=self.pre)
        head = struct.pack("<IIB", ck, int(valid.sum()), len(map_shape))
        head += struct.pack(f"<{len(map_shape)}I", *map_shape)
        head += struct.pack("<B", _dtype_code(labels.dtype))
        head += np.packbits(valid).tobytes()
        head += struct.pack("<H", len(params)) + params
        head += np.ascontiguousarray(labels[valid]).tobytes()
        return _pack_header(self.MSG_TYPE, self.codec.code,
                            head + payload_rows, checksum=checksum)

    @classmethod
    def decode(cls, wire: bytes):
        """-> (acts (nvalid, *map_shape) f32, labels (nvalid,), valid
        (nvalid,) all-True), as jnp arrays: exactly what the server
        received, ready for MetaTraining. (The invalid slots never crossed
        the wire, so the reconstruction is the valid rows — the server
        trains on what arrived, which also keeps junk slots out of the
        upper model's batch statistics.)

        Every malformation raises a ``FrameError`` subclass: offsets are
        bounds-checked before each read (``TruncatedFrame``), the bitmap
        popcount must equal the declared valid count and the codec's row
        payload must be exactly the bytes the row count implies
        (``LengthMismatch``), unknown codec/dtype codes get their typed
        errors — corrupted frames never escape as ``struct.error`` /
        ``IndexError`` / numpy ``ValueError``."""
        msg_type, codec_code, payload = _unpack_header(wire)
        if msg_type != cls.MSG_TYPE:
            raise WrongMessageType(
                f"expected SelectedKnowledge, got {msg_type}")
        codec = codec_by_code(codec_code)
        _need(payload, 0, 9, "knowledge head")
        ck, nvalid, ndim = struct.unpack_from("<IIB", payload, 0)
        off = 9
        _need(payload, off, 4 * ndim, "map shape")
        map_shape = struct.unpack_from(f"<{ndim}I", payload, off)
        off += 4 * ndim
        _need(payload, off, 1, "label dtype code")
        (lab_code,) = struct.unpack_from("<B", payload, off)
        off += 1
        if lab_code >= len(_DTYPES):
            raise UnknownDtype(f"label dtype code {lab_code} outside the "
                               f"wire table (0..{len(_DTYPES) - 1})")
        nbitmap = (ck + 7) // 8
        _need(payload, off, nbitmap, "validity bitmap")
        valid = np.unpackbits(
            np.frombuffer(payload, np.uint8, nbitmap, off),
            count=ck).astype(bool)
        off += nbitmap
        if int(valid.sum()) != nvalid:   # before nvalid slices labels/rows
            raise LengthMismatch(
                f"frame bitmap popcount {int(valid.sum())} != {nvalid}")
        _need(payload, off, 2, "codec param length")
        (nparams,) = struct.unpack_from("<H", payload, off)
        off += 2
        _need(payload, off, nparams, "codec params")
        params = payload[off:off + nparams]
        off += nparams
        lab_dt = _DTYPES[lab_code]
        _need(payload, off, nvalid * lab_dt.itemsize, "labels")
        labels = np.frombuffer(payload, lab_dt, nvalid, off).copy()
        off += nvalid * lab_dt.itemsize
        d = 1                            # Python ints: no corrupt-dim overflow
        for s in map_shape:
            d *= int(s)
        rows = codec.decode(payload[off:], nvalid, d, params)
        acts = rows.reshape((nvalid,) + tuple(map_shape))
        return (jnp.asarray(acts), jnp.asarray(labels),
                jnp.ones((nvalid,), bool))
