"""repro.fl.transport — the wire-format + codec subsystem every FL exchange
flows through.

Three layers:
  ``messages``  typed frames (WeightBroadcast / SelectedKnowledge /
                UpperUpdate) with an encode/decode round-trip contract
  ``codecs``    raw_f32 / f16 / int8 tensor codecs (int8's quantize hot
                path is the fused Pallas kernel in kernels/quantize.py)
  ``channel``   ledger-charging helpers the round engines call — every
                CommLedger entry is ``len(encode())``, byte-true

A fourth surface, ``errors``, is the decode side's failure contract: every
malformed wire buffer raises a typed ``FrameError`` (never a raw
struct/IndexError/numpy crash), which is what makes bounded
retry-with-backoff in ``repro.fl.faults`` possible.

See README.md's communication and fault-tolerance sections for the wire
layout (v2: flags byte + optional CRC32 trailer) and the measured tables
(benchmarks/comm_bench.py -> BENCH_comms.json, benchmarks/chaos_bench.py
-> BENCH_faults.json).
"""
from repro.fl.transport.channel import (Channel, broadcast_weights,
                                        knowledge_codec, prequantize_cohort,
                                        upload_knowledge,
                                        upload_knowledge_batched,
                                        upload_update)
from repro.fl.transport.codecs import (Int8Codec, Quantized, TensorCodec,
                                       codec_by_code, get_codec)
from repro.fl.transport.errors import (BadMagic, BadVersion, ChecksumMismatch,
                                       FrameError, LengthMismatch,
                                       TruncatedFrame, UnknownCodec,
                                       UnknownDtype, WrongMessageType)
from repro.fl.transport.messages import (CRC_BYTES, HEADER_BYTES,
                                         SelectedKnowledge, UpperUpdate,
                                         WeightBroadcast, pytree_frame_nbytes,
                                         unflatten_like)

__all__ = [
    "BadMagic", "BadVersion", "CRC_BYTES", "Channel", "ChecksumMismatch",
    "FrameError", "HEADER_BYTES", "Int8Codec", "LengthMismatch", "Quantized",
    "SelectedKnowledge", "TensorCodec", "TruncatedFrame", "UnknownCodec",
    "UnknownDtype", "UpperUpdate", "WeightBroadcast", "WrongMessageType",
    "broadcast_weights", "codec_by_code", "get_codec", "knowledge_codec",
    "prequantize_cohort", "pytree_frame_nbytes", "unflatten_like",
    "upload_knowledge", "upload_knowledge_batched", "upload_update",
]
