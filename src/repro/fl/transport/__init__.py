"""repro.fl.transport — the wire-format + codec subsystem every FL exchange
flows through.

Three layers:
  ``messages``  typed frames (WeightBroadcast / SelectedKnowledge /
                UpperUpdate) with an encode/decode round-trip contract
  ``codecs``    raw_f32 / f16 / int8 tensor codecs (int8's quantize hot
                path is the fused Pallas kernel in kernels/quantize.py)
  ``channel``   ledger-charging helpers the round engines call — every
                CommLedger entry is ``len(encode())``, byte-true

See README.md's communication section for the wire layout and the measured
bytes-per-round table (benchmarks/comm_bench.py -> BENCH_comms.json).
"""
from repro.fl.transport.channel import (broadcast_weights, knowledge_codec,
                                        prequantize_cohort, upload_knowledge,
                                        upload_knowledge_batched,
                                        upload_update)
from repro.fl.transport.codecs import (Int8Codec, Quantized, TensorCodec,
                                       codec_by_code, get_codec)
from repro.fl.transport.messages import (HEADER_BYTES, SelectedKnowledge,
                                         UpperUpdate, WeightBroadcast,
                                         pytree_frame_nbytes, unflatten_like)

__all__ = [
    "HEADER_BYTES", "Int8Codec", "Quantized", "SelectedKnowledge",
    "TensorCodec", "UpperUpdate", "WeightBroadcast", "broadcast_weights",
    "codec_by_code", "get_codec", "knowledge_codec", "prequantize_cohort",
    "pytree_frame_nbytes", "unflatten_like", "upload_knowledge",
    "upload_knowledge_batched", "upload_update",
]
