"""Typed frame errors — the decode side's contract with the runtime.

Every way a wire buffer can fail to decode maps to ONE exception family,
``FrameError``, so callers (the retry loop in ``repro.fl.faults``, the
round engines, the fuzz tests) can distinguish "the network mangled this
frame, retransmission may help" from a genuine protocol bug — and never
see a raw ``struct.error`` / ``IndexError`` / numpy ``ValueError`` escape
the decoder (the pre-hierarchy crash modes).

``FrameError`` subclasses ``ValueError`` so existing callers that caught
the decoder's old ad-hoc ``ValueError``s keep working unchanged.

The taxonomy, roughly in the order decode hits them:

  TruncatedFrame    the buffer ends before a field it promises
  BadMagic          the first 4 bytes are not b"FLTP"
  BadVersion        a version (or flag bit) this decoder does not speak
  ChecksumMismatch  the CRC32 trailer disagrees with the received bytes
  WrongMessageType  a valid frame of a different message type
  UnknownCodec      the header names a codec wire id we don't have
  UnknownDtype      an array block names a dtype code off the table
  LengthMismatch    internal lengths disagree (payload vs header length,
                    bitmap popcount vs valid count, codec payload vs the
                    row count it must reconstruct, trailing garbage)

Retriability: every subclass can be caused by in-flight corruption of a
well-formed frame, so the fault runtime treats the whole family as
retriable; distinguishing systematic peer bugs (e.g. persistent
BadVersion) is the caller's policy, via the type.
"""
from __future__ import annotations


class FrameError(ValueError):
    """A wire buffer that is not a decodable frame. Base of the family —
    catch this to mean 'corrupt or foreign bytes', not a programming
    error."""


class TruncatedFrame(FrameError):
    """The buffer is shorter than a length it declares (or than the fixed
    header itself)."""


class BadMagic(FrameError):
    """The frame does not start with the FLTP magic."""


class BadVersion(FrameError):
    """A frame version (or flags bit) this decoder does not implement."""


class ChecksumMismatch(FrameError):
    """The CRC32 trailer does not match the received header+payload."""


class WrongMessageType(FrameError):
    """A structurally valid frame of a different message type than the
    caller asked to decode."""


class UnknownCodec(FrameError):
    """The header's codec wire id is not in the codec registry."""


class UnknownDtype(FrameError):
    """An array block's dtype code is outside the wire dtype table."""


class LengthMismatch(FrameError):
    """Two lengths that must agree do not (header vs payload, bitmap
    popcount vs valid count, codec payload vs expected row bytes,
    trailing garbage after the last field)."""
