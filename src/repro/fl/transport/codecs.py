"""Tensor codecs for the wire: how a float payload becomes bytes.

A codec owns the LOSSY part of the transport layer — turning the selected
activation maps (the paper's 'knowledge') into a wire dtype — while
``messages.py`` owns the lossless framing around it. Three codecs:

  raw_f32   4 bytes/element, exact (the paper's implicit accounting)
  f16       2 bytes/element, IEEE half round-trip
  int8      1 byte/element + 8 bytes of per-tensor affine params
            (xmin, scale), quantized by the fused Pallas kernel
            (``kernels/quantize.py``) or its jnp oracle — bit-identical
            either way, and vmappable so the distributed engine encodes a
            whole stacked cohort inside one compiled computation.

``encode`` consumes the FULL fixed-slot tensor plus the valid mask (the
int8 statistics must see exactly the rows that will cross the wire;
empty-cluster slots are masked out of them), and returns the wire buffer
for the valid rows only plus the codec's parameter bytes. ``decode``
reconstructs those rows as f32. Codec choice changes bytes-per-round and
(for lossy codecs) what the server's MetaTraining actually sees — both ends
of the paper's accuracy-vs-communication trade.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Type

import jax.numpy as jnp
import numpy as np

from repro.fl.transport.errors import LengthMismatch, UnknownCodec
from repro.kernels import ref as kref


def _check_rows(payload: bytes, nvalid: int, d: int, itemsize: int,
                name: str) -> None:
    """A decode-side guard shared by every codec: the row payload must be
    EXACTLY the bytes the declared row count implies — a truncated or
    padded row block is wire corruption (``LengthMismatch``), never a
    numpy ``frombuffer``/``reshape`` ValueError escaping to the caller."""
    want = nvalid * d * itemsize
    if len(payload) != want:
        raise LengthMismatch(
            f"{name} row payload is {len(payload)} bytes, expected "
            f"{want} ({nvalid} rows x {d} x {itemsize}B)")


@dataclass(frozen=True)
class Quantized:
    """A tensor already through the quantize hot path (possibly inside a
    vmapped computation): the int8 levels plus the affine params."""
    q: np.ndarray          # (N, D) int8, masked rows = -128
    xmin: float
    scale: float


class TensorCodec:
    """encode: (x (N, D) f32, valid (N,) bool) -> (payload bytes for the
    VALID rows, params bytes). decode: inverse, -> (nvalid, D) f32.
    When a ``pre``-quantized payload is supplied, ``x`` may be None — the
    framing layer skips the host copy the codec would never read."""
    name: str = ""
    code: int = -1

    def encode(self, x: np.ndarray, valid: np.ndarray,
               pre: Optional[Quantized] = None) -> Tuple[bytes, bytes]:
        """(full slot tensor, valid mask) -> (valid-row payload bytes,
        codec param bytes). ``pre`` hands in an already-quantized payload
        (batched cohort path); codecs without a quantize stage ignore it."""
        raise NotImplementedError

    def decode(self, payload: bytes, nvalid: int, d: int,
               params: bytes) -> np.ndarray:
        """Inverse of ``encode``: payload + declared (nvalid, d) + param
        bytes -> (nvalid, d) f32. Any size/params mismatch raises
        ``LengthMismatch`` — wire corruption, never a numpy escape."""
        raise NotImplementedError


class RawF32Codec(TensorCodec):
    """Exact 4-bytes/element wire dtype (the paper's implicit accounting):
    payload = valid rows as little-endian f32, no codec params."""
    name, code = "raw_f32", 0

    def encode(self, x, valid, pre=None):
        """Valid rows -> contiguous f32 bytes; params are empty."""
        return np.ascontiguousarray(
            x[valid].astype(np.float32)).tobytes(), b""

    def decode(self, payload, nvalid, d, params):
        """f32 bytes -> (nvalid, d) f32 copy; non-empty params are
        corruption (this codec never writes any)."""
        _check_rows(payload, nvalid, d, 4, self.name)
        if params:
            raise LengthMismatch(
                f"{self.name} takes no codec params, got {len(params)}B")
        return np.frombuffer(payload, np.float32).reshape(nvalid, d).copy()


class F16Codec(TensorCodec):
    """IEEE-754 half codec: 2 bytes/element, round-to-nearest-even on
    encode, exact widening back to f32 on decode, no codec params."""
    name, code = "f16", 1

    def encode(self, x, valid, pre=None):
        """Valid rows cast to f16 -> contiguous bytes; params are empty."""
        return np.ascontiguousarray(
            x[valid].astype(np.float16)).tobytes(), b""

    def decode(self, payload, nvalid, d, params):
        """f16 bytes -> (nvalid, d) widened to f32 (exact: every half is
        representable); non-empty params are corruption."""
        _check_rows(payload, nvalid, d, 2, self.name)
        if params:
            raise LengthMismatch(
                f"{self.name} takes no codec params, got {len(params)}B")
        half = np.frombuffer(payload, np.float16).reshape(nvalid, d)
        return half.astype(np.float32)


class Int8Codec(TensorCodec):
    """Per-tensor affine int8: q = clip(round((x - xmin) * (1/scale)) - 128)
    with (xmin, scale) over the valid rows (``kernels/ref.py`` is the exact
    contract). ``use_pallas`` routes the quantize through the fused Pallas
    kernel; the jnp oracle is bit-identical, so the wire bytes do not depend
    on the engine. A pre-quantized ``Quantized`` (from the batched cohort
    path) skips the per-client quantize entirely."""
    name, code = "int8", 2

    def __init__(self, use_pallas: bool = False):
        self.use_pallas = use_pallas

    def quantize(self, x, valid) -> Quantized:
        """Run the affine-int8 hot path over one tensor: (x, valid) ->
        ``Quantized`` levels + (xmin, scale), via the Pallas kernel or the
        bit-identical jnp oracle (``use_pallas``)."""
        x2 = jnp.asarray(np.ascontiguousarray(x, np.float32))
        m = jnp.asarray(np.ascontiguousarray(valid, bool))
        if self.use_pallas:
            from repro.kernels.ops import quantize_affine
            q, xmin, scale = quantize_affine(x2, m)
        else:
            q, xmin, scale = kref.quantize_affine_ref(x2, m)
        return Quantized(np.asarray(q), float(xmin), float(scale))

    def encode(self, x, valid, pre=None):
        """Valid rows as int8 levels + 8 param bytes ``<ff`` (xmin, scale).
        ``pre`` (a ``Quantized`` from the vmapped cohort quantize) skips
        the per-client kernel call — identical wire bytes either way."""
        z = pre if pre is not None else self.quantize(x, valid)
        params = struct.pack("<ff", z.xmin, z.scale)
        return np.ascontiguousarray(z.q[valid]).tobytes(), params

    def decode(self, payload, nvalid, d, params):
        """int8 levels + ``<ff`` params -> (nvalid, d) f32 via the dequant
        contract below; params must be exactly 8 bytes."""
        _check_rows(payload, nvalid, d, 1, self.name)
        if len(params) != 8:
            raise LengthMismatch(
                f"{self.name} needs 8 param bytes (xmin, scale), "
                f"got {len(params)}")
        xmin, scale = struct.unpack("<ff", params)
        q = np.frombuffer(payload, np.int8).reshape(nvalid, d)
        # the dequant contract (kernels/ref.py): x_hat = (q+128)*scale+xmin,
        # in f32 end to end so every consumer reconstructs identical values
        return ((q.astype(np.float32) + np.float32(128.0))
                * np.float32(scale) + np.float32(xmin))


_CODECS: Dict[str, Type[TensorCodec]] = {
    c.name: c for c in (RawF32Codec, F16Codec, Int8Codec)}
_BY_CODE: Dict[int, Type[TensorCodec]] = {
    c.code: c for c in (RawF32Codec, F16Codec, Int8Codec)}


def get_codec(name: str, use_pallas: bool = False) -> TensorCodec:
    """Codec registry keyed by ``FLConfig.transport_codec``."""
    if name not in _CODECS:
        raise ValueError(
            f"unknown transport codec {name!r} (have {sorted(_CODECS)})")
    if name == "int8":
        return Int8Codec(use_pallas=use_pallas)
    return _CODECS[name]()


def codec_by_code(code: int) -> TensorCodec:
    """Wire-id -> codec (decode side; the frame header names the codec, so
    a receiver never needs out-of-band codec config). A code outside the
    registry is wire corruption, not a config error: ``UnknownCodec``."""
    if code not in _BY_CODE:
        raise UnknownCodec(f"unknown codec wire id {code}")
    return _BY_CODE[code]()
