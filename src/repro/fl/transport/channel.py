"""The charging surface between the round engines and the wire.

``rounds.client_round``, ``distributed.cohort_round`` and
``FLServer.broadcast_weights`` call these helpers instead of estimating
sizes: every helper builds the real frame, charges the CommLedger with
``len(wire)`` — the exact bytes — and hands back what the RECEIVER decodes,
so a lossy codec's effect on MetaTraining is observable end to end, not
just its byte count.

``upload_knowledge_batched`` is the stacked-cohort entry: for the int8
codec it runs ONE vmapped quantize over the gathered
``(sel_acts, sel_y, valid)`` triple (the Pallas kernel or its oracle —
bit-identical), then frames each client's bytes from the pre-quantized
levels; the per-client and batched encodings produce identical wire bytes,
which is what keeps the sequential and distributed simulator paths
ledger-equal.
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.comms import CommLedger
from repro.fl.transport.codecs import (Int8Codec, Quantized, TensorCodec,
                                       get_codec)
from repro.fl.transport.messages import SelectedKnowledge, pytree_frame_nbytes

PyTree = Any


def broadcast_weights(ledger: CommLedger, params: PyTree,
                      num_clients: int) -> int:
    """server -> cohort: one WeightBroadcast frame per member, charged at
    its exact encoded size (native dtypes — a bf16 model costs half an f32
    model, where the old ``size * 4`` billed both the same). The length is
    computed from leaf shapes/dtypes (``pytree_frame_nbytes`` ==
    ``len(encode())``) — the simulator's receiver reads the in-memory
    params, so serializing the full model just to measure it would be a
    per-round device->host copy for nothing."""
    nbytes = pytree_frame_nbytes(params)
    ledger.download("weights", nbytes * num_clients, frames=num_clients)
    return nbytes * num_clients


def upload_update(ledger: CommLedger, params: PyTree) -> int:
    """client -> server: the UpperUpdate frame for Eq. 2. Returns bytes
    (shape/dtype-computed, same rationale as ``broadcast_weights``)."""
    nbytes = pytree_frame_nbytes(params)
    ledger.upload("weights", nbytes)
    return nbytes


def upload_knowledge(ledger: CommLedger, acts, labels, valid,
                     codec: TensorCodec,
                     pre: Optional[Quantized] = None) -> Tuple:
    """client -> server: encode the selection triple, charge the exact
    frame bytes, and return what the server DECODES from the wire
    (valid rows only, dequantized f32) — the metadata MetaTraining sees."""
    wire = SelectedKnowledge(acts, labels, valid, codec, pre=pre).encode()
    ledger.upload("metadata", len(wire))
    return SelectedKnowledge.decode(wire)


def prequantize_cohort(codec: TensorCodec, sel_acts: jnp.ndarray,
                       valid: jnp.ndarray) -> Optional[List[Quantized]]:
    """One compiled (vmappable) quantize over a stacked cohort's gathered
    triple: (B, CK, ...) acts + (B, CK) valid -> per-client Quantized, or
    None for codecs with no quantize stage. Per-client statistics are
    reductions over each client's own rows, so the vmapped result is
    bit-identical to B separate quantizes — same wire bytes either way."""
    if not isinstance(codec, Int8Codec):
        return None
    b, ck = sel_acts.shape[0], sel_acts.shape[1]
    flat = jnp.reshape(sel_acts, (b, ck, -1)).astype(jnp.float32)
    m = jnp.asarray(valid).astype(bool)
    if codec.use_pallas:
        from repro.kernels.ops import quantize_affine
        q, xmin, scale = jax.vmap(quantize_affine)(flat, m)
    else:
        from repro.kernels.ref import quantize_affine_ref
        q, xmin, scale = jax.vmap(quantize_affine_ref)(flat, m)
    q, xmin, scale = np.asarray(q), np.asarray(xmin), np.asarray(scale)
    return [Quantized(q[i], float(xmin[i]), float(scale[i]))
            for i in range(b)]


def upload_knowledge_batched(ledger: CommLedger, sel_acts, sel_ys, valid,
                             codec: TensorCodec) -> List[Tuple]:
    """Stacked-cohort knowledge upload: encode every client's frame (int8
    quantize runs once, vmapped, over the whole stack), charge each frame's
    exact bytes, and return the per-client decoded triples."""
    pres = prequantize_cohort(codec, jnp.asarray(sel_acts),
                              jnp.asarray(valid))
    out = []
    for i in range(np.asarray(valid).shape[0]):
        out.append(upload_knowledge(
            ledger, sel_acts[i], sel_ys[i], valid[i], codec,
            pre=None if pres is None else pres[i]))
    return out


def knowledge_codec(cfg) -> TensorCodec:
    """The codec an FLConfig asks for (``transport_codec`` knob; the Pallas
    quantize engine rides the same ``use_pallas_selection`` switch as the
    selection kernels — one hot-path toggle for the whole client side)."""
    return get_codec(cfg.transport_codec,
                     use_pallas=cfg.use_pallas_selection)
