"""The charging surface between the round engines and the wire.

``rounds.client_round``, ``distributed.cohort_round`` and
``FLServer.broadcast_weights`` talk to a :class:`Channel` instead of
estimating sizes: every method builds (or arithmetically sizes) the real
frame, charges the CommLedger with ``len(wire)`` — the exact bytes — and
hands back what the RECEIVER decodes, so a lossy codec's effect on
MetaTraining is observable end to end, not just its byte count.

``Channel`` is the PERFECT wire: every frame arrives intact, exactly once.
``repro.fl.faults.FaultyChannel`` subclasses it to inject deterministic
client crashes, bit-flips, truncations and duplicate deliveries between
``encode`` and ``decode`` — the round engines cannot tell the difference,
which is what keeps the zero-fault path bit-identical to a channel-less
run (ledger included: the perfect channel charges the same arithmetic
frame sizes as the historical module-level helpers, which remain below as
thin wrappers).

``upload_knowledge_batched`` is the stacked-cohort entry: for the int8
codec it runs ONE vmapped quantize over the gathered
``(sel_acts, sel_y, valid)`` triple (the Pallas kernel or its oracle —
bit-identical), then frames each client's bytes from the pre-quantized
levels; the per-client and batched encodings produce identical wire bytes,
which is what keeps the sequential and distributed simulator paths
ledger-equal.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.fl.comms import CommLedger
from repro.fl.transport.codecs import (Int8Codec, Quantized, TensorCodec,
                                       get_codec)
from repro.fl.transport.messages import SelectedKnowledge, pytree_frame_nbytes

PyTree = Any


class Channel:
    """The perfect wire: encode -> charge exact bytes -> decode, every
    frame delivered intact exactly once. ``checksum`` appends the v2 CRC32
    trailer to every frame (4 bytes/frame in the ledger); off by default so
    the fault-free ledger stays byte-identical to the pre-checksum wire.

    The fault-tolerance surface (overridden by ``FaultyChannel``):
    ``begin_round`` resets per-round state, ``update_arrived`` reports
    whether a client's UpperUpdate frame decoded (always True here), and
    ``round_stats`` returns the per-round fault counters (all zero here).
    """

    def __init__(self, ledger: CommLedger, checksum: bool = False):
        self.ledger = ledger
        self.checksum = checksum

    # ---- fault surface (no-ops on the perfect wire) ----
    def begin_round(self, round_idx: int) -> None:
        """Reset per-round wire state. A no-op on the perfect wire; fault
        models key their RNG streams and fate tables off ``round_idx``."""
        pass

    def update_arrived(self, client_id: int) -> bool:
        """Whether ``client_id``'s UpperUpdate frame decoded this round —
        the per-client bit behind the arrival mask in Eq. 2."""
        return True

    def round_stats(self) -> dict:
        """Per-round fault counters (see ``FaultyChannel``); the perfect
        wire reports zeros so callers need not special-case it."""
        return {"corruptions_detected": 0, "retransmits": 0,
                "duplicates": 0, "silent_corruptions": 0,
                "injected_corruptions": 0, "lost_frames": 0,
                "backoff_s": 0.0}

    def decoded_update(self, client_id: int) -> Optional[PyTree]:
        """The update pytree as the server decoded it, when the channel
        had to materialize one (None on the perfect wire: the frame is
        lossless and intact, so the in-memory params ARE the decode)."""
        return None

    # ---- the three frame kinds ----
    def broadcast_weights(self, params: PyTree, num_clients: int) -> int:
        """server -> cohort: one WeightBroadcast frame per member, charged
        at its exact encoded size (native dtypes — a bf16 model costs half
        an f32 model, where the old ``size * 4`` billed both the same).
        The length is computed from leaf shapes/dtypes
        (``pytree_frame_nbytes`` == ``len(encode())``) — the simulator's
        receiver reads the in-memory params, so serializing the full model
        just to measure it would be a per-round device->host copy for
        nothing."""
        nbytes = pytree_frame_nbytes(params, checksum=self.checksum)
        self.ledger.download("weights", nbytes * num_clients,
                             frames=num_clients)
        return nbytes * num_clients

    def upload_update(self, client_id: int, params: PyTree) -> bool:
        """client -> server: the UpperUpdate frame for Eq. 2. Returns
        whether it arrived (always True on the perfect wire; the bytes are
        shape/dtype-computed, same rationale as ``broadcast_weights``)."""
        nbytes = pytree_frame_nbytes(params, checksum=self.checksum)
        self.ledger.upload("weights", nbytes)
        return True

    def upload_knowledge(self, client_id: int, acts, labels, valid,
                         codec: TensorCodec,
                         pre: Optional[Quantized] = None) -> Optional[Tuple]:
        """client -> server: encode the selection triple, charge the exact
        frame bytes, and return what the server DECODES from the wire
        (valid rows only, dequantized f32) — the metadata MetaTraining
        sees. None means the frame never arrived (faulty channels only)."""
        with obs.span("encode", frame="knowledge", client=int(client_id)):
            wire = SelectedKnowledge(acts, labels, valid, codec,
                                     pre=pre).encode(checksum=self.checksum)
        self.ledger.upload("metadata", len(wire))
        with obs.span("decode", frame="knowledge", client=int(client_id)):
            return SelectedKnowledge.decode(wire)

    def upload_knowledge_batched(self, client_ids: Sequence[int], sel_acts,
                                 sel_ys, valid,
                                 codec: TensorCodec) -> List[Optional[Tuple]]:
        """Stacked-cohort knowledge upload: encode every client's frame
        (int8 quantize runs once, vmapped, over the whole stack), charge
        each frame's exact bytes, and return the per-client decoded
        triples (None per client whose frame was lost)."""
        pres = prequantize_cohort(codec, jnp.asarray(sel_acts),
                                  jnp.asarray(valid))
        out = []
        for i, cid in enumerate(client_ids):
            out.append(self.upload_knowledge(
                cid, sel_acts[i], sel_ys[i], valid[i], codec,
                pre=None if pres is None else pres[i]))
        return out


# --------------------------------------------------------------------------
# module-level helpers — the historical API, kept as thin perfect-wire
# wrappers (tests and external callers use these directly)
# --------------------------------------------------------------------------
def broadcast_weights(ledger: CommLedger, params: PyTree,
                      num_clients: int) -> int:
    """Perfect-wire ``Channel.broadcast_weights`` (exact per-member frame
    bytes charged to ``ledger``); returns total bytes charged."""
    return Channel(ledger).broadcast_weights(params, num_clients)


def upload_update(ledger: CommLedger, params: PyTree) -> int:
    """client -> server: the UpperUpdate frame for Eq. 2. Returns bytes
    (shape/dtype-computed)."""
    nbytes = pytree_frame_nbytes(params)
    ledger.upload("weights", nbytes)
    return nbytes


def upload_knowledge(ledger: CommLedger, acts, labels, valid,
                     codec: TensorCodec,
                     pre: Optional[Quantized] = None) -> Tuple:
    """Perfect-wire ``Channel.upload_knowledge`` for a single client:
    encode, charge exact frame bytes, return the decoded triple."""
    return Channel(ledger).upload_knowledge(0, acts, labels, valid, codec,
                                            pre=pre)


def prequantize_cohort(codec: TensorCodec, sel_acts: jnp.ndarray,
                       valid: jnp.ndarray) -> Optional[List[Quantized]]:
    """One compiled (vmappable) quantize over a stacked cohort's gathered
    triple: (B, CK, ...) acts + (B, CK) valid -> per-client Quantized, or
    None for codecs with no quantize stage. Per-client statistics are
    reductions over each client's own rows, so the vmapped result is
    bit-identical to B separate quantizes — same wire bytes either way."""
    if not isinstance(codec, Int8Codec):
        return None
    b, ck = sel_acts.shape[0], sel_acts.shape[1]
    flat = jnp.reshape(sel_acts, (b, ck, -1)).astype(jnp.float32)
    m = jnp.asarray(valid).astype(bool)
    if codec.use_pallas:
        from repro.kernels.ops import quantize_affine
        q, xmin, scale = jax.vmap(quantize_affine)(flat, m)
    else:
        from repro.kernels.ref import quantize_affine_ref
        q, xmin, scale = jax.vmap(quantize_affine_ref)(flat, m)
    q, xmin, scale = np.asarray(q), np.asarray(xmin), np.asarray(scale)
    return [Quantized(q[i], float(xmin[i]), float(scale[i]))
            for i in range(b)]


def upload_knowledge_batched(ledger: CommLedger, sel_acts, sel_ys, valid,
                             codec: TensorCodec) -> List[Tuple]:
    """Perfect-wire ``Channel.upload_knowledge_batched`` over a stacked
    cohort (clients numbered 0..B-1): one vmapped quantize, per-frame
    exact byte charges, per-client decoded triples."""
    return Channel(ledger).upload_knowledge_batched(
        range(np.asarray(valid).shape[0]), sel_acts, sel_ys, valid, codec)


def knowledge_codec(cfg) -> TensorCodec:
    """The codec an FLConfig asks for (``transport_codec`` knob; the Pallas
    quantize engine rides the same ``use_pallas_selection`` switch as the
    selection kernels — one hot-path toggle for the whole client side)."""
    return get_codec(cfg.transport_codec,
                     use_pallas=cfg.use_pallas_selection)
