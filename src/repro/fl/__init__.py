from repro.fl.comms import CommLedger

__all__ = ["CommLedger", "FLSimulation", "SimulationResult", "FLClient",
           "FLServer"]


def __getattr__(name):   # lazy: simulation imports core.rounds (cycle guard)
    if name in ("FLSimulation", "SimulationResult"):
        from repro.fl import simulation
        return getattr(simulation, name)
    if name == "FLClient":
        from repro.fl.client import FLClient
        return FLClient
    if name == "FLServer":
        from repro.fl.server import FLServer
        return FLServer
    raise AttributeError(name)
