"""Communication accounting — the paper's efficiency claim made measurable.

Every client->server (upload) and server->client (download) transfer is
logged by category; ``summary()`` yields the bytes table used by the
communication benchmark (metadata bytes with selection vs without is the
paper's '<1% of the data' claim)."""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field


@dataclass
class CommLedger:
    up: dict = field(default_factory=lambda: defaultdict(int))
    down: dict = field(default_factory=lambda: defaultdict(int))

    def upload(self, category: str, nbytes: int):
        self.up[category] += int(nbytes)

    def download(self, category: str, nbytes: int):
        self.down[category] += int(nbytes)

    @property
    def total_up(self) -> int:
        return sum(self.up.values())

    @property
    def total_down(self) -> int:
        return sum(self.down.values())

    def summary(self) -> dict:
        return {"up": dict(self.up), "down": dict(self.down),
                "total_up": self.total_up, "total_down": self.total_down}

    def reset(self):
        self.up.clear()
        self.down.clear()
