"""Byte-true communication ledger — the measurement half of the paper's
efficiency claim.

The ledger no longer estimates anything: every entry is charged by
``repro.fl.transport`` with the EXACT length of an encoded wire frame
(``len(WeightBroadcast/SelectedKnowledge/UpperUpdate.encode())``), so
``summary()`` is a byte-for-byte account of what a real deployment would
put on the network — framing, validity bitmaps, codec parameters and all.
The old ``size * 4`` accounting miscounted every non-f32 payload (bf16
weights billed at 2x their size) and could not see codec choice at all;
with the transport layer, switching ``FLConfig.transport_codec`` between
``raw_f32``/``f16``/``int8`` moves these numbers exactly the way it moves
real bytes (benchmarks/comm_bench.py -> BENCH_comms.json).

Uploads (client -> server) and downloads (server -> client) are tallied by
category — ``"metadata"`` for SelectedKnowledge frames (the paper's ~1.6%
claim lives here), ``"weights"`` for WeightBroadcast/UpperUpdate — along
with per-category frame counts (one frame = one encoded message), so
bytes-per-frame is recoverable without re-running.

Fault tolerance adds two categories the perfect wire never charges:
``"retransmit"`` for every re-send of a frame whose previous delivery
failed to decode (the recovery overhead the chaos benchmark reports), and
``"duplicate"`` for network-cloned deliveries the receiver deduplicates.
Both are real traffic — they count toward ``total_up`` — but are kept out
of ``"metadata"``/``"weights"`` so the paper's efficiency numbers stay
attributable to first transmissions."""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field

# fault-runtime charging categories (repro.fl.faults.FaultyChannel)
RETRANSMIT = "retransmit"
DUPLICATE = "duplicate"


@dataclass
class CommLedger:
    up: dict = field(default_factory=lambda: defaultdict(int))
    down: dict = field(default_factory=lambda: defaultdict(int))
    up_frames: dict = field(default_factory=lambda: defaultdict(int))
    down_frames: dict = field(default_factory=lambda: defaultdict(int))

    def upload(self, category: str, nbytes: int, frames: int = 1):
        self.up[category] += int(nbytes)
        self.up_frames[category] += int(frames)

    def download(self, category: str, nbytes: int, frames: int = 1):
        self.down[category] += int(nbytes)
        self.down_frames[category] += int(frames)

    @property
    def total_up(self) -> int:
        return sum(self.up.values())

    @property
    def total_down(self) -> int:
        return sum(self.down.values())

    def summary(self) -> dict:
        return {"up": dict(self.up), "down": dict(self.down),
                "up_frames": dict(self.up_frames),
                "down_frames": dict(self.down_frames),
                "total_up": self.total_up, "total_down": self.total_down,
                "retransmit_up": self.up.get(RETRANSMIT, 0),
                "duplicate_up": self.up.get(DUPLICATE, 0)}

    def reset(self):
        self.up.clear()
        self.down.clear()
        self.up_frames.clear()
        self.down_frames.clear()
