"""repro.fl.faults — deterministic fault injection for the FL runtime.

The paper's setting (heterogeneous edge clients pushing selected knowledge
over constrained networks) is exactly the regime where clients crash
mid-round and uploads arrive truncated or bit-flipped; the client-selection
survey (arXiv 2211.01549) catalogs dropout and unreliability as first-order
FL systems concerns. This module makes those failures a REPRODUCIBLE
experiment instead of an outage:

  FaultPlan      the fault model — per-round client crash probabilities
                 (before any upload vs. after the knowledge upload), per-
                 frame bit-flip / truncation / duplicate-delivery
                 probabilities, and the recovery policy (retry budget +
                 exponential backoff).
  FaultyChannel  a ``transport.Channel`` that injects the plan between
                 ``encode`` and ``decode``. Corruption lands on the real
                 wire bytes, so what the server sees is whatever the typed
                 decoder makes of the mangled frame: a ``FrameError``
                 (detected -> bounded retry, each retransmit charged real
                 bytes under the ledger's ``retransmit`` category) or —
                 only possible with checksums off — a silently wrong
                 payload, which is counted so benchmarks can prove the
                 CRC closes that hole.

Determinism: every random decision is drawn from a stream seeded by
``(seed, round, client, stream-kind)`` — not from call order — so the same
plan produces the SAME faults on the sequential, batched and distributed
engines, and a chaos run is exactly repeatable. With every rate at zero the
channel never perturbs, never retries, and charges byte-identical ledger
entries to the perfect ``Channel``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.fl.comms import DUPLICATE, RETRANSMIT, CommLedger
from repro.fl.transport.channel import Channel
from repro.fl.transport.errors import FrameError
from repro.fl.transport.messages import (SelectedKnowledge, UpperUpdate,
                                         unflatten_like)

PyTree = Any

# client fates for one round (drawn once per (round, client))
FATE_OK = "ok"
FATE_CRASH_BEFORE_UPLOAD = "crash_before_upload"   # nothing arrives
FATE_CRASH_AFTER_SELECT = "crash_after_select"     # knowledge arrives,
                                                   # update doesn't

# per-client RNG stream ids (call-order independent determinism)
_STREAM_FATE = 0
_STREAM_KNOWLEDGE = 1
_STREAM_UPDATE = 2


@dataclass(frozen=True)
class FaultPlan:
    """The fault model plus the recovery policy, all in one frozen value
    (hashable, loggable, sweepable by the chaos benchmark).

    Rates are probabilities per round (crashes) or per frame delivery
    (corruption); a frame draws at most ONE corruption event per attempt
    (truncation, else bit-flip), keeping ``truncate_rate``/``bitflip_rate``
    directly interpretable. ``max_retries`` bounds how often a DETECTED
    corruption is retransmitted (each retransmit charges real bytes);
    ``backoff_base`` is the virtual exponential-backoff unit the fault log
    accumulates (simulated seconds — the simulator does not sleep)."""
    drop_rate: float = 0.0          # P[client crashes before any upload]
    late_crash_rate: float = 0.0    # P[crash after the knowledge upload]
    bitflip_rate: float = 0.0       # P[a delivery gets one bit flipped]
    truncate_rate: float = 0.0      # P[a delivery is cut short]
    duplicate_rate: float = 0.0     # P[a delivery is cloned in flight]
    max_retries: int = 2            # retransmit budget per frame
    backoff_base: float = 0.05      # virtual seconds; delay 2x per retry

    @property
    def any_faults(self) -> bool:
        return any(r > 0 for r in (self.drop_rate, self.late_crash_rate,
                                   self.bitflip_rate, self.truncate_rate,
                                   self.duplicate_rate))


@dataclass
class FaultEvent:
    """One line of the per-round fault log."""
    round_idx: int
    client_id: int
    frame: str                      # "knowledge" | "update"
    kind: str                       # fate / "corrupt_detected" / ...
    attempt: int
    detail: str = ""


class FaultyChannel(Channel):
    """A ``Channel`` whose wire obeys a :class:`FaultPlan`.

    Delivery of one frame: charge the sender's bytes (attempt 0 under the
    frame's own category, retries under ``retransmit``), perturb per the
    plan, hand the bytes to the real decoder. ``FrameError`` -> detected
    corruption, retry after (virtual) backoff until the budget runs out;
    a perturbed frame that DECODES is a silent corruption (possible only
    without checksums) and is returned as-is — garbage the server will
    consume, exactly as a real deployment would. Duplicate deliveries
    charge their clone's bytes under ``duplicate`` and are deduplicated by
    the receiver.

    ``checksum`` defaults to True here (unlike the perfect wire): a chaos
    run without frame integrity is the pathology the benchmark exists to
    demonstrate, not the default configuration.
    """

    def __init__(self, ledger: CommLedger, plan: FaultPlan, seed: int = 0,
                 checksum: bool = True):
        super().__init__(ledger, checksum=checksum)
        self.plan, self.seed = plan, seed
        self.round_idx = 0
        self.log: List[FaultEvent] = []
        # run-cumulative (never reset): the zero-silent-acceptance audit
        self.total_silent_corruptions = 0
        self.total_injected_corruptions = 0
        self._begin()

    # ---- per-round state ----
    def _begin(self) -> None:
        self._fates: dict = {}
        self._arrived: dict = {}
        self._decoded: dict = {}
        self._stats = {"corruptions_detected": 0, "retransmits": 0,
                       "duplicates": 0, "silent_corruptions": 0,
                       "injected_corruptions": 0, "lost_frames": 0,
                       "backoff_s": 0.0}

    def begin_round(self, round_idx: int) -> None:
        self.round_idx = round_idx
        self.log = []
        self._begin()

    def round_stats(self) -> dict:
        return dict(self._stats)

    def update_arrived(self, client_id: int) -> bool:
        return self._arrived.get(int(client_id), True)

    def decoded_update(self, client_id: int) -> Optional[PyTree]:
        """The client's update as the server decoded it — differs from the
        in-memory params only when a corrupted frame was silently accepted
        (checksums off); None when the frame never arrived or arrived
        intact."""
        return self._decoded.get(int(client_id))

    # ---- deterministic draws ----
    def _rng(self, client_id: int, stream: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence(
            (int(self.seed), int(self.round_idx), int(client_id), stream)))

    def client_fate(self, client_id: int) -> str:
        """The client's fate this round, drawn once per (round, client) —
        identical whichever engine (sequential/batched/distributed) asks,
        and whatever order the cohort is processed in."""
        cid = int(client_id)
        if cid not in self._fates:
            u = float(self._rng(cid, _STREAM_FATE).random())
            if u < self.plan.drop_rate:
                fate = FATE_CRASH_BEFORE_UPLOAD
            elif u < self.plan.drop_rate + self.plan.late_crash_rate:
                fate = FATE_CRASH_AFTER_SELECT
            else:
                fate = FATE_OK
            self._fates[cid] = fate
            if fate != FATE_OK:
                self._log(cid, "client", fate, 0)
        return self._fates[cid]

    # ---- the wire ----
    def _log(self, client_id: int, frame: str, kind: str, attempt: int,
             detail: str = "") -> None:
        self.log.append(FaultEvent(self.round_idx, int(client_id), frame,
                                   kind, attempt, detail))
        # mirror into the trace (no-ops when observability is off): every
        # fault-log line becomes a point event + a counter, so chaos traces
        # carry the same counts BENCH_faults.json reports
        obs.event("fault." + kind, round=self.round_idx,
                  client=int(client_id), frame=frame, attempt=attempt,
                  detail=detail)
        obs.inc("fault." + kind)

    def _perturb(self, wire: bytes,
                 rng: np.random.Generator) -> Tuple[bytes, Optional[str]]:
        """At most one corruption event per delivery attempt."""
        if rng.random() < self.plan.truncate_rate and len(wire) > 0:
            cut = int(rng.integers(0, len(wire)))
            return wire[:cut], "truncate"
        if rng.random() < self.plan.bitflip_rate and len(wire) > 0:
            pos = int(rng.integers(0, len(wire) * 8))
            buf = bytearray(wire)
            buf[pos // 8] ^= 1 << (pos % 8)
            return bytes(buf), "bitflip"
        return wire, None

    def _deliver(self, client_id: int, wire: bytes, category: str,
                 decode: Callable[[bytes], Any], frame: str,
                 stream: int) -> Tuple[Optional[Any], bool]:
        """One frame through the faulty wire with the bounded
        retry-with-backoff budget. Returns (decode, silently_corrupted);
        decode is None once the budget is exhausted (the frame is lost;
        arrival masks take over)."""
        rng = self._rng(client_id, stream)
        for attempt in range(self.plan.max_retries + 1):
            cat = category if attempt == 0 else RETRANSMIT
            if attempt:
                self._stats["retransmits"] += 1
                self._stats["backoff_s"] += (self.plan.backoff_base
                                             * 2.0 ** (attempt - 1))
                obs.inc("fault.retransmits")
            self.ledger.upload(cat, len(wire))
            delivered, event = self._perturb(wire, rng)
            if event is not None:
                self._stats["injected_corruptions"] += 1
                self.total_injected_corruptions += 1
                obs.inc("fault.injected_corruptions")
            if rng.random() < self.plan.duplicate_rate:
                # the network clones the delivery; the receiver dedups but
                # the clone's bytes were real traffic
                self.ledger.upload(DUPLICATE, len(delivered))
                self._stats["duplicates"] += 1
                self._log(client_id, frame, "duplicate", attempt)
            try:
                out = decode(delivered)
            except FrameError as e:
                self._stats["corruptions_detected"] += 1
                self._log(client_id, frame, "corrupt_detected", attempt,
                          type(e).__name__)
                continue
            if event is not None:
                # only reachable with checksums off: the mangled frame
                # still decoded — the server now consumes wrong data
                self._stats["silent_corruptions"] += 1
                self.total_silent_corruptions += 1
                self._log(client_id, frame, "silent_corruption", attempt,
                          event)
            return out, event is not None
        self._stats["lost_frames"] += 1
        self._log(client_id, frame, "gave_up", self.plan.max_retries)
        return None, False

    def upload_knowledge(self, client_id, acts, labels, valid, codec,
                         pre=None):
        if self.client_fate(client_id) == FATE_CRASH_BEFORE_UPLOAD:
            return None
        wire = SelectedKnowledge(acts, labels, valid, codec,
                                 pre=pre).encode(checksum=self.checksum)
        out, _ = self._deliver(client_id, wire, "metadata",
                               SelectedKnowledge.decode, "knowledge",
                               _STREAM_KNOWLEDGE)
        return out

    def upload_update(self, client_id, params):
        cid = int(client_id)
        if self.client_fate(cid) != FATE_OK:
            self._arrived[cid] = False
            return False
        wire = UpperUpdate(params).encode(checksum=self.checksum)
        leaves, silent = self._deliver(cid, wire, "weights",
                                       UpperUpdate.decode, "update",
                                       _STREAM_UPDATE)
        self._arrived[cid] = leaves is not None
        if leaves is not None and silent:
            # materialize the decode only when it can differ from the
            # in-memory params (this frame was silently corrupted in
            # flight yet still decoded — checksums off)
            self._decoded[cid] = unflatten_like(params, leaves)
        return self._arrived[cid]
