"""Pytree checkpointing on npz: flatten with '/'-joined key paths, save
atomically, restore into the original structure. No orbax dependency —
works for FL round state (global weights + round counter + rng) and for the
LM training loop.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> tuple:
    """Returns (flat dict of npz-safe arrays, dtype map for ml_dtypes leaves
    like bfloat16 that np.savez can't round-trip — stored as uint16 views)."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
            dtypes[key] = arr.dtype.name        # e.g. "bfloat16"
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        flat[key] = arr
    return flat, dtypes


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metadata: Optional[dict] = None) -> str:
    """Atomic save: write to tmp then rename. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    flat, dtypes = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    meta = dict(metadata or {}, step=step, __dtypes__=dtypes)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, target: PyTree,
                       step: Optional[int] = None) -> tuple:
    """Restore into ``target``'s structure. Returns (tree, metadata)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["__meta__"]))
        flat = {k: data[k] for k in data.files if k != "__meta__"}
    dtypes = meta.pop("__dtypes__", {})
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for path_elems, leaf in paths_leaves:
        key = _SEP.join(_path_str(p) for p in path_elems)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if key in dtypes:                        # e.g. bfloat16 stored as u16
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, dtypes[key])))
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


class CheckpointManager:
    """Keeps the last ``max_to_keep`` checkpoints in a directory."""

    def __init__(self, directory: str, max_to_keep: int = 3):
        self.directory = directory
        self.max_to_keep = max_to_keep

    def save(self, step: int, tree: PyTree, metadata: Optional[dict] = None):
        path = save_checkpoint(self.directory, step, tree, metadata)
        steps = sorted(int(m.group(1)) for f in os.listdir(self.directory)
                       if (m := re.match(r"ckpt_(\d+)\.npz$", f)))
        for s in steps[:-self.max_to_keep]:
            os.unlink(os.path.join(self.directory, f"ckpt_{s:08d}.npz"))
        return path

    def restore(self, target: PyTree, step: Optional[int] = None):
        return restore_checkpoint(self.directory, target, step)

    @property
    def latest(self) -> Optional[int]:
        return latest_step(self.directory)
