"""repro.obs.registry — the benchmark run-registry and regression gate.

Every BENCH_*.json in this repo is written through :func:`write_bench`,
which also appends a fingerprinted record (flattened scalars + claims +
jax/backend/host/git fingerprint) to the append-only
``experiments/bench_history.jsonl``.  ``python -m repro.obs regress``
then compares a current BENCH file against that trajectory with
noise-aware thresholds: per scalar, fail only outside
``median ± k·MAD`` *in the direction that is worse* for that metric, and
hard-fail any ``claims`` flag that was true in every historical run and
is false now.  flcheck rule ``OBS002`` bans ad-hoc ``open(...BENCH_...)``
writes in ``benchmarks/`` so history capture can't be bypassed.

Pure stdlib — importable (and runnable, for the regress CLI) without jax.
"""
from __future__ import annotations

import json
import os
import platform
import re
import subprocess
import time
from typing import Any, Dict, List, Optional

SCHEMA = "repro.obs.bench/v1"

_BENCH_NAME_RE = re.compile(r"^BENCH_(.+)\.json$")


def bench_name(path: str) -> Optional[str]:
    """'/x/BENCH_selection.json' -> 'selection' (None if not a BENCH file)."""
    m = _BENCH_NAME_RE.match(os.path.basename(path))
    return m.group(1) if m else None


def default_history_path(bench_path: str) -> str:
    """BENCH files live at the repo root; history lives in the sibling
    ``experiments/bench_history.jsonl``."""
    root = os.path.dirname(os.path.abspath(bench_path))
    return os.path.join(root, "experiments", "bench_history.jsonl")


# --------------------------------------------------------------------------
# record construction
# --------------------------------------------------------------------------
def flatten_scalars(obj: Any, prefix: str = "",
                    out: Optional[Dict[str, float]] = None,
                    depth: int = 8) -> Dict[str, float]:
    """Dotted-key view of every numeric leaf in a bench report (bools are
    claims, not scalars; lists are samples, not trajectory points)."""
    if out is None:
        out = {}
    if depth < 0:
        return out
    if isinstance(obj, dict):
        for k, v in obj.items():
            key = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, bool):
                continue
            if isinstance(v, (int, float)):
                out[key] = float(v)
            elif isinstance(v, dict):
                flatten_scalars(v, key, out, depth - 1)
    return out


def fingerprint() -> Dict[str, Any]:
    """Enough provenance to explain an outlier: software versions, the
    accelerator backend, the host, and the git rev that produced it."""
    info: Dict[str, Any] = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "host": platform.node(),
    }
    try:
        import jax
        info["jax"] = jax.__version__
        info["backend"] = jax.default_backend()
    except Exception:
        info["jax"] = None
        info["backend"] = None
    try:
        rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        info["git_rev"] = rev.stdout.strip() if rev.returncode == 0 else None
    except Exception:
        info["git_rev"] = None
    return info


def history_record(name: str, report: Dict[str, Any]) -> Dict[str, Any]:
    """One ``bench_history.jsonl`` line: schema, bench name, timestamp,
    flattened scalars, boolean claims, environment fingerprint."""
    claims = report.get("claims", {})
    return {"schema": SCHEMA, "bench": name, "ts": time.time(),
            "scalars": flatten_scalars(report),
            "claims": {k: bool(v) for k, v in claims.items()},
            "fingerprint": fingerprint()}


# --------------------------------------------------------------------------
# the one writer
# --------------------------------------------------------------------------
def write_bench(bench_path: str, report: Dict[str, Any], *,
                name: Optional[str] = None,
                history_path: Optional[str] = None,
                history: bool = True) -> Dict[str, Any]:
    """Write a BENCH_*.json AND append its fingerprinted record to the
    run history (the only sanctioned way to emit a bench file — flcheck
    OBS002).  Returns the appended record."""
    if name is None:
        name = bench_name(bench_path) or os.path.basename(bench_path)
    with open(bench_path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    rec = history_record(name, report)
    if history:
        hpath = history_path or default_history_path(bench_path)
        os.makedirs(os.path.dirname(hpath) or ".", exist_ok=True)
        with open(hpath, "a") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
    return rec


def load_history(path: str) -> List[Dict[str, Any]]:
    """All records from a history JSONL ([] if the file doesn't exist —
    first run bootstraps cleanly). Malformed lines raise ValueError."""
    if not os.path.exists(path):
        return []
    recs = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: malformed history line "
                                 f"({e})") from e
    return recs


# --------------------------------------------------------------------------
# noise-aware regression gate
# --------------------------------------------------------------------------
def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def scalar_direction(key: str) -> Optional[str]:
    """Which direction is *worse* for a scalar, by key convention:
    'high_bad' (times, bytes, overheads), 'low_bad' (throughputs,
    accuracies, utilizations, speedups), or None (ungated, noted only)."""
    last = key.split(".")[-1]
    if last.endswith(("_s", "_us", "_ms", "_bytes", "_overhead")) \
            or last in ("overhead_frac", "bytes_per_round", "wall"):
        return "high_bad"
    if last.endswith(("_per_sec", "_per_s", "_acc", "_accuracy",
                      "_speedup", "_agreement")) \
            or last in ("accuracy", "utilization", "speedup",
                        "records_per_sec", "selection_agreement"):
        return "low_bad"
    return None


def regress_report(name: str, report: Dict[str, Any],
                   history: List[Dict[str, Any]], *, k: float = 4.0,
                   min_history: int = 3,
                   rel_floor: float = 0.05) -> Dict[str, Any]:
    """Compare one current bench report against its trajectory.

    Per scalar with >= ``min_history`` history points: fail if the
    current value lies outside ``median ± k * scale`` on the *worse* side,
    where ``scale = max(MAD, rel_floor·|median|)`` — the MAD floor keeps a
    freakishly quiet history from flagging normal jitter.  Scalars with
    no worse-direction convention only produce notes.  Claims that were
    true in **all** history runs and are false now always fail.
    """
    recs = [r for r in history if r.get("bench") == name]
    out: Dict[str, Any] = {"bench": name, "history_points": len(recs),
                           "failures": [], "notes": [], "checked": 0}
    if not recs:
        out["notes"].append("no history for this bench yet (bootstrap run)")

    for ckey, cval in report.get("claims", {}).items():
        hist = [bool(r["claims"][ckey]) for r in recs
                if ckey in r.get("claims", {})]
        if hist and all(hist) and not cval:
            out["failures"].append(
                f"claim '{ckey}' flipped FALSE (true in all "
                f"{len(hist)} history runs)")

    cur = flatten_scalars(report)
    for key in sorted(cur):
        series = [r["scalars"][key] for r in recs
                  if key in r.get("scalars", {})
                  and isinstance(r["scalars"][key], (int, float))]
        if len(series) < min_history:
            continue
        med = _median(series)
        mad = _median([abs(x - med) for x in series])
        scale = max(mad, rel_floor * abs(med), 1e-12)
        val, direction = cur[key], scalar_direction(key)
        hi, lo = med + k * scale, med - k * scale
        out["checked"] += 1
        desc = (f"{key}: {val:.6g} vs median {med:.6g} "
                f"± {k:g}·{scale:.3g} over {len(series)} runs")
        if direction == "high_bad" and val > hi:
            out["failures"].append(f"regression (higher is worse) {desc}")
        elif direction == "low_bad" and val < lo:
            out["failures"].append(f"regression (lower is worse) {desc}")
        elif direction is None and (val > hi or val < lo):
            out["notes"].append(f"drifted (ungated) {desc}")
    return out
