"""repro.obs — observability for the FL runtime: span tracing, a metrics
registry with a byte-true CommLedger bridge, and block-until-ready-aware
profiling hooks around the Pallas kernels and round phases.

One knob: ``FLConfig.observability`` (default off).  Off, every hook in
the runtime resolves to the shared ``NULL_TRACER``/``NULL_SPAN``
singletons — no jax calls, no device syncs, no allocation — so disabled
runs are bit-identical to the uninstrumented code, ledger included.  On,
``FLSimulation`` owns a ``Tracer`` whose trace serializes as
schema-versioned JSONL (``repro.obs.tracer.SCHEMA``):

    sim = FLSimulation(..., cfg=replace(cfg, observability=True))
    res = sim.run(rounds=3)
    sim.tracer.write_jsonl("trace.jsonl")
    # then: python -m repro.obs summarize trace.jsonl
    #       python -m repro.obs export-chrome trace.jsonl out.json
    #       python -m repro.obs diff a.jsonl b.jsonl

Instrumentation idiom (all no-ops when disabled)::

    with obs.timed_block("kernel.kmeans_lloyd", n=n, k=k) as sp:
        out = kernel(...)
        out = sp.sync(out)        # block_until_ready only when tracing
    obs.inc("fault.retransmits")
    obs.gauge("fl.stragglers", late)
    obs.event("selection_sketch", client=3, occupancy=...)

Import-safe without jax — the flcheck CI job (no jax installed) imports
``repro.obs.timing`` through this package.
"""
from __future__ import annotations

from typing import Any

from repro.obs import registry, timing
from repro.obs.metrics import (NULL_METRICS, Counter, Gauge, Histogram,
                               MeteredLedger, MetricsRegistry, NullMetrics)
from repro.obs.profile import (CostRecord, ProfiledFunction, peak_table,
                               profiled_jit, roofline)
from repro.obs.registry import regress_report, write_bench
from repro.obs.tracer import (NULL_SPAN, NULL_TRACER, SCHEMA, NullTracer,
                              Span, TraceError, Tracer, get_tracer,
                              load_trace, span_paths, to_chrome, use_tracer)

__all__ = [
    "timing", "SCHEMA", "Tracer", "NullTracer", "NULL_TRACER", "Span",
    "NULL_SPAN", "TraceError", "load_trace", "span_paths", "to_chrome",
    "get_tracer", "use_tracer", "span", "timed_block", "event", "inc",
    "gauge", "MetricsRegistry", "NullMetrics", "NULL_METRICS", "Counter",
    "Gauge", "Histogram", "MeteredLedger", "CostRecord", "ProfiledFunction",
    "profiled_jit", "peak_table", "roofline", "registry", "write_bench",
    "regress_report",
]


def span(name: str, **attrs: Any):
    """Open a span on the active tracer (``NULL_SPAN`` when off).  Must
    be used as a ``with`` item — flcheck OBS001 flags bare calls."""
    return get_tracer().span(name, **attrs)


# Same hook, named for the kernel/phase profiling sites: a timed block
# whose ``sp.sync(out)`` makes async device work count inside the block.
timed_block = span


def event(name: str, **attrs: Any) -> None:
    """Record a point event on the active tracer."""
    get_tracer().event(name, **attrs)


def inc(name: str, value: int = 1) -> None:
    """Increment a counter on the active tracer's metrics registry."""
    get_tracer().metrics.counter(name).inc(value)


def gauge(name: str, value: float) -> None:
    """Set a gauge on the active tracer's metrics registry."""
    get_tracer().metrics.gauge(name).set(value)
