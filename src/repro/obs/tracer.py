"""repro.obs.tracer — nested span tracing for the FL runtime.

A ``Tracer`` records a tree of wall-clock spans (``round -> broadcast ->
client -> select -> local_update -> ...``) plus point events and a
byte-attribution table fed by the ``CommLedger`` bridge
(``repro.obs.metrics.MeteredLedger``), and serializes the whole run as
schema-versioned JSONL (``SCHEMA``).  ``python -m repro.obs`` summarizes,
diffs, and exports traces to Chrome trace-event format.

The hooks sprinkled through the runtime go through the *active tracer*
(``get_tracer``/``use_tracer``) so no call signature has to thread a
tracer argument.  When no tracer is active the singleton ``NULL_TRACER``
is returned and every hook — ``span``/``event``/``inc``/``gauge``/
``Span.sync`` — is a no-op on shared singletons: no jax calls, no
allocation, no device syncs, which is what keeps observability-off runs
bit-identical to the seed.

Import-safe without jax (the flcheck CI job imports this transitively);
jax is only touched lazily inside ``Span.sync``.
"""
from __future__ import annotations

import json
from collections import defaultdict
from typing import Any, Dict, Iterator, List, Optional

from repro.obs.metrics import (NULL_METRICS, MetricsRegistry)
from repro.obs.timing import monotonic, sync as _device_sync

SCHEMA = "repro.obs.trace/v1"


class _NullSpan:
    """Shared no-op span: the body of every ``with obs.span(...)`` hook
    when observability is off.  ``sync`` is the identity (no
    block_until_ready => zero perturbation of async dispatch)."""
    __slots__ = ()
    enabled = False
    name = ""
    duration = 0.0

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None

    def sync(self, x: Any) -> Any:
        return x

    def set(self, **attrs: Any) -> None:
        return None


NULL_SPAN = _NullSpan()


class Span:
    """One timed block in the trace tree.  Use as a context manager
    (flcheck OBS001 flags spans opened without ``with``)."""
    __slots__ = ("tracer", "span_id", "parent_id", "name", "attrs",
                 "t0", "t1", "bytes", "frames")
    enabled = True

    def __init__(self, tracer: "Tracer", span_id: int,
                 parent_id: Optional[int], name: str,
                 attrs: Dict[str, Any]) -> None:
        self.tracer = tracer
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.t1 = 0.0
        self.bytes: Dict[str, int] = {}
        self.frames: Dict[str, int] = {}

    def __enter__(self) -> "Span":
        self.tracer._stack.append(self)
        self.t0 = monotonic()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.t1 = monotonic()
        top = self.tracer._stack.pop()
        if top is not self:  # pragma: no cover - programming error guard
            raise RuntimeError(
                f"span stack corrupted: closed {self.name!r}, top was "
                f"{top.name!r}")
        # cost-annotated span (obs.profile attached flops + peaks): now
        # that the duration is known, derive hardware utilization — not
        # for "traced" spans, whose wall covers trace time, not device
        # time, and a utilization from it would be fiction
        a = self.attrs
        if "flops" in a and a.get("peak_flops") and "traced" not in a:
            dur = self.t1 - self.t0
            if dur > 0:
                a["utilization"] = a["flops"] / dur / a["peak_flops"]
                if a.get("hbm_bytes") and a.get("peak_hbm_bw"):
                    a["hbm_utilization"] = (a["hbm_bytes"] / dur
                                            / a["peak_hbm_bw"])
        self.tracer.spans.append(self)

    @property
    def duration(self) -> float:
        """Wall seconds between enter and exit (0 while still open)."""
        return self.t1 - self.t0

    def sync(self, x: Any) -> Any:
        """Block until device work backing ``x`` is done so the span
        covers it (identity on tracers during jit tracing — the span is
        then marked ``traced`` because it measured trace time, not
        device time)."""
        if x is None:
            return x
        if _has_jax_tracer(x):
            self.attrs["traced"] = True
            return x
        return _device_sync(x)

    def set(self, **attrs: Any) -> None:
        """Attach/overwrite span attributes after entry."""
        self.attrs.update(attrs)

    def charge(self, direction: str, category: str, nbytes: int,
               frames: int) -> None:
        """Accumulate ledger bytes/frames under ``direction/category`` —
        called by ``Tracer.on_ledger`` for the innermost open span."""
        key = f"{direction}/{category}"
        self.bytes[key] = self.bytes.get(key, 0) + int(nbytes)
        self.frames[key] = self.frames.get(key, 0) + int(frames)

    def to_record(self) -> Dict[str, Any]:
        """The span's trace-file JSON record (attrs/bytes only if any)."""
        rec: Dict[str, Any] = {"type": "span", "id": self.span_id,
                               "parent": self.parent_id, "name": self.name,
                               "t0": self.t0, "t1": self.t1}
        if self.attrs:
            rec["attrs"] = self.attrs
        if self.bytes:
            rec["bytes"] = self.bytes
            rec["frames"] = self.frames
        return rec


def _has_jax_tracer(x: Any) -> bool:
    try:
        import jax
    except ImportError:  # pragma: no cover
        return False
    return any(isinstance(l, jax.core.Tracer)
               for l in jax.tree_util.tree_leaves(x))


class NullTracer:
    """The inert tracer: every hook is a no-op returning shared
    singletons.  Active whenever ``FLConfig.observability`` is off."""
    __slots__ = ()
    enabled = False
    metrics = NULL_METRICS

    def span(self, name: str, **attrs: Any) -> _NullSpan:
        """The shared no-op span (still a context manager)."""
        return NULL_SPAN

    def event(self, name: str, **attrs: Any) -> None:
        """Dropped."""
        return None

    def on_ledger(self, direction: str, category: str, nbytes: int,
                  frames: int) -> None:
        """Dropped (the ledger itself still books the bytes)."""
        return None

    def current(self) -> None:
        """Always None: no span is ever open."""
        return None


NULL_TRACER = NullTracer()


class Tracer:
    """Records spans/events/metrics for one run and serializes them."""
    enabled = True

    def __init__(self, meta: Optional[Dict[str, Any]] = None) -> None:
        self.meta = dict(meta or {})
        self.metrics = MetricsRegistry()
        self.spans: List[Span] = []          # finished, in close order
        self.events: List[Dict[str, Any]] = []
        self.unattributed: Dict[str, int] = defaultdict(int)
        self._stack: List[Span] = []
        self._next_id = 1

    # -- recording ---------------------------------------------------
    def span(self, name: str, **attrs: Any) -> Span:
        """New child span of the innermost open span (parent captured at
        creation); must be used as a ``with`` context manager."""
        sid = self._next_id
        self._next_id += 1
        parent = self._stack[-1].span_id if self._stack else None
        return Span(self, sid, parent, name, attrs)

    def event(self, name: str, **attrs: Any) -> None:
        """Record an instantaneous event under the current span."""
        parent = self._stack[-1].span_id if self._stack else None
        self.events.append({"type": "event", "name": name,
                            "ts": monotonic(), "parent": parent,
                            "attrs": attrs})

    def current(self) -> Optional[Span]:
        """The innermost open span, or None outside any span."""
        return self._stack[-1] if self._stack else None

    def on_ledger(self, direction: str, category: str, nbytes: int,
                  frames: int) -> None:
        """CommLedger bridge: attribute a byte charge to the open span
        (or the ``unattributed`` bucket, which trace-completeness checks
        require to stay empty) and mirror it into metrics counters."""
        cur = self.current()
        if cur is not None:
            cur.charge(direction, category, nbytes, frames)
        else:
            self.unattributed[f"{direction}/{category}"] += int(nbytes)
        self.metrics.counter(f"ledger.{direction}.{category}.bytes").inc(nbytes)
        self.metrics.counter(f"ledger.{direction}.{category}.frames").inc(frames)

    # -- rollups -----------------------------------------------------
    def attributed_bytes(self) -> Dict[str, int]:
        """Total bytes per ``direction/category`` summed over all spans
        (open spans included).  Completeness means this equals the
        ledger's own totals and ``unattributed`` is empty."""
        out: Dict[str, int] = defaultdict(int)
        for sp in list(self.spans) + list(self._stack):
            for key, n in sp.bytes.items():
                out[key] += n
        return dict(out)

    def child_durations(self, parent: Span) -> Dict[str, float]:
        """Wall seconds of ``parent``'s direct children, summed by span
        name — the per-phase timing dict ``SimulationResult`` carries."""
        out: Dict[str, float] = {}
        for sp in self.spans:
            if sp.parent_id == parent.span_id:
                out[sp.name] = out.get(sp.name, 0.0) + sp.duration
        return out

    # -- serialization -----------------------------------------------
    def to_records(self) -> List[Dict[str, Any]]:
        """Full trace as JSON records: header, spans (close order),
        events, then the metrics snapshot + unattributed tail."""
        header = {"type": "header", "schema": SCHEMA, "meta": self.meta}
        tail: List[Dict[str, Any]] = [
            {"type": "metrics", "snapshot": self.metrics.snapshot(),
             "unattributed": dict(self.unattributed)}]
        return ([header] + [sp.to_record() for sp in self.spans]
                + list(self.events) + tail)

    def write_jsonl(self, path: str) -> None:
        """Serialize ``to_records()`` to a JSONL trace file (the format
        ``python -m repro.obs`` reads)."""
        with open(path, "w") as f:
            for rec in self.to_records():
                f.write(json.dumps(rec) + "\n")


# -- trace files (reader side; used by the CLI and tests) ------------

class TraceError(ValueError):
    """Malformed or wrong-schema trace file."""


def load_trace(path: str) -> Dict[str, Any]:
    """Parse a trace JSONL file into
    ``{"header", "spans", "events", "metrics"}``; raises ``TraceError``
    on missing/mismatched schema header or bad JSON."""
    header = None
    spans: List[Dict[str, Any]] = []
    events: List[Dict[str, Any]] = []
    metrics: Dict[str, Any] = {"snapshot": {}, "unattributed": {}}
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise TraceError(f"{path}:{i + 1}: bad JSON: {e}") from e
            kind = rec.get("type")
            if i == 0:
                if kind != "header" or rec.get("schema") != SCHEMA:
                    raise TraceError(
                        f"{path}: missing/unsupported trace header "
                        f"(want schema {SCHEMA!r}, got "
                        f"{rec.get('schema')!r})")
                header = rec
                continue
            if kind == "span":
                spans.append(rec)
            elif kind == "event":
                events.append(rec)
            elif kind == "metrics":
                metrics = rec
    if header is None:
        raise TraceError(f"{path}: empty trace file")
    return {"header": header, "spans": spans, "events": events,
            "metrics": metrics}


def span_paths(trace: Dict[str, Any]) -> Dict[str, Dict[str, int]]:
    """Collapse a loaded trace's span tree to ``name/path`` ->
    ``{count, bytes}`` — the wall-time-free structural signature ``diff``
    compares."""
    by_id = {sp["id"]: sp for sp in trace["spans"]}

    def path(sp: Dict[str, Any]) -> str:
        parts = [sp["name"]]
        pid = sp.get("parent")
        guard = 0
        while pid is not None and pid in by_id and guard < 64:
            parts.append(by_id[pid]["name"])
            pid = by_id[pid].get("parent")
            guard += 1
        return "/".join(reversed(parts))

    out: Dict[str, Dict[str, int]] = {}
    for sp in trace["spans"]:
        p = path(sp)
        slot = out.setdefault(p, {"count": 0, "bytes": 0})
        slot["count"] += 1
        slot["bytes"] += sum(sp.get("bytes", {}).values())
    return out


def to_chrome(trace: Dict[str, Any]) -> Dict[str, Any]:
    """Chrome trace-event JSON (load in chrome://tracing / Perfetto):
    spans as complete ('X') events, point events as instants ('i'),
    timestamps in microseconds relative to the first span."""
    t_base = min([sp["t0"] for sp in trace["spans"]]
                 + [ev["ts"] for ev in trace["events"]], default=0.0)
    out: List[Dict[str, Any]] = []
    for sp in trace["spans"]:
        args = dict(sp.get("attrs", {}))
        if sp.get("bytes"):
            args["bytes"] = sp["bytes"]
        out.append({"ph": "X", "name": sp["name"], "pid": 1, "tid": 1,
                    "ts": (sp["t0"] - t_base) * 1e6,
                    "dur": (sp["t1"] - sp["t0"]) * 1e6, "args": args})
    for ev in trace["events"]:
        out.append({"ph": "i", "name": ev["name"], "pid": 1, "tid": 1,
                    "ts": (ev["ts"] - t_base) * 1e6, "s": "g",
                    "args": ev.get("attrs", {})})
    return {"traceEvents": out,
            "otherData": {"schema": trace["header"]["schema"],
                          "meta": trace["header"].get("meta", {})}}


# -- active-tracer plumbing ------------------------------------------

_ACTIVE: List[Any] = [NULL_TRACER]


def get_tracer() -> Any:
    """The tracer the instrumentation hooks report to (NULL_TRACER when
    observability is off)."""
    return _ACTIVE[-1]


class use_tracer:
    """``with use_tracer(t): ...`` installs ``t`` as the active tracer
    for the dynamic extent of the block."""

    def __init__(self, tracer: Any) -> None:
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def __enter__(self) -> Any:
        _ACTIVE.append(self.tracer)
        return self.tracer

    def __exit__(self, *exc: Any) -> None:
        _ACTIVE.pop()
