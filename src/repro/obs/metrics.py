"""repro.obs.metrics — counters / gauges / histograms + the CommLedger
bridge.

``MetricsRegistry`` is the mutable store a ``Tracer`` owns;
``NULL_METRICS`` is the inert twin every hook touches when observability
is off (shared no-op instruments, zero allocation).

``MeteredLedger`` is the bridge: a ``CommLedger`` subclass whose
``upload``/``download`` forward each charge to the tracer *after* normal
bookkeeping — the ledger stays the single byte-true source (no double
bookkeeping), the tracer only attributes the same bytes to spans and
mirrors them into counters.

Import-safe without jax: ``repro.fl.comms`` is pure stdlib.
"""
from __future__ import annotations

from typing import Any, Dict, List

from repro.fl.comms import CommLedger


class Counter:
    """Monotonically increasing integer-ish metric."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, value: int = 1) -> None:
        """Add ``value`` (default 1); never decremented."""
        self.value += value


class Gauge:
    """Last-write-wins instantaneous value."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        """Overwrite the current value (last write wins)."""
        self.value = value


class Histogram:
    """Streaming summary: count/sum/min/max (enough for latency and
    size distributions without bucket-boundary bikeshedding)."""
    __slots__ = ("count", "total", "min", "max")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        """Fold one sample into count/sum/min/max."""
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def summary(self) -> Dict[str, float]:
        """count/sum (+ min/max/mean once non-empty) as a plain dict."""
        if not self.count:
            return {"count": 0, "sum": 0.0}
        return {"count": self.count, "sum": self.total, "min": self.min,
                "max": self.max, "mean": self.total / self.count}


class _NullCounter:
    __slots__ = ()
    value = 0

    def inc(self, value: int = 1) -> None:
        return None


class _NullGauge:
    __slots__ = ()
    value = 0.0

    def set(self, value: float) -> None:
        return None


class _NullHistogram:
    __slots__ = ()
    count = 0

    def observe(self, value: float) -> None:
        return None

    def summary(self) -> Dict[str, float]:
        return {"count": 0, "sum": 0.0}


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()


class MetricsRegistry:
    """Create-on-first-use named instruments."""

    def __init__(self) -> None:
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        """The named counter, created on first use."""
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        """The named gauge, created on first use."""
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        """The named histogram, created on first use."""
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        return h

    def snapshot(self) -> Dict[str, Any]:
        """Name-sorted {counters, gauges, histograms} values — the
        ``metrics.snapshot`` record in a trace file."""
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self.histograms.items())},
        }


class NullMetrics:
    """Inert registry: every instrument is a shared no-op singleton."""
    __slots__ = ()

    def counter(self, name: str) -> _NullCounter:
        """The shared no-op counter, whatever the name."""
        return _NULL_COUNTER

    def gauge(self, name: str) -> _NullGauge:
        """The shared no-op gauge, whatever the name."""
        return _NULL_GAUGE

    def histogram(self, name: str) -> _NullHistogram:
        """The shared no-op histogram, whatever the name."""
        return _NULL_HISTOGRAM

    def snapshot(self) -> Dict[str, Any]:
        """Empty snapshot in the same shape as ``MetricsRegistry``."""
        return {"counters": {}, "gauges": {}, "histograms": {}}


NULL_METRICS = NullMetrics()


class MeteredLedger(CommLedger):
    """A ``CommLedger`` that mirrors every charge to a tracer.

    Byte totals live only in the ``CommLedger`` fields (``summary()``
    etc. are inherited unchanged); the tracer sees each charge once, for
    span attribution + metrics.  Swapped in for ``FLServer.ledger``
    before the channel is built, so every wire charge of the run flows
    through it.
    """

    def __init__(self, tracer: Any) -> None:
        super().__init__()
        self.tracer = tracer

    def upload(self, category: str, nbytes: int, frames: int = 1) -> None:
        """Normal ledger charge, then one ``on_ledger(\"up\", ...)``."""
        super().upload(category, nbytes, frames)
        self.tracer.on_ledger("up", category, nbytes, frames)

    def download(self, category: str, nbytes: int, frames: int = 1) -> None:
        """Normal ledger charge, then one ``on_ledger(\"down\", ...)``."""
        super().download(category, nbytes, frames)
        self.tracer.on_ledger("down", category, nbytes, frames)
