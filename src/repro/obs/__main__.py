"""Trace-file and bench-trajectory CLI.

  python -m repro.obs summarize TRACE.jsonl
  python -m repro.obs export-chrome TRACE.jsonl OUT.json
  python -m repro.obs diff A.jsonl B.jsonl
  python -m repro.obs regress [BENCH.json ...] [--history H.jsonl]

Exit codes: 0 ok / traces structurally identical; 1 diff found a
difference / regress found a regression; 2 usage or unreadable input.

``diff`` compares structure, not wall time (two runs never agree on
nanoseconds): span counts and ledger bytes per span path, event counts
per name, and metrics counters — exactly the signals that must not move
when a change claims to be byte- and shape-neutral.

``regress`` compares current BENCH_*.json files (default: all of them
under ``benchmarks/``) against the append-only run history written by
``repro.obs.registry`` with noise-aware thresholds — per scalar,
median ± k·MAD over the trajectory, failing only in the direction that
is worse — and hard-fails any ``claims`` flag that was true in every
historical run and is false now.  An empty or missing history bootstraps
cleanly (exit 0): the first run *is* the trajectory.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Any, Dict

from repro.obs import registry
from repro.obs.tracer import TraceError, load_trace, span_paths, to_chrome


def _load(path: str) -> Dict[str, Any]:
    try:
        return load_trace(path)
    except (OSError, TraceError) as e:
        print(f"error: {e}", file=sys.stderr)
        raise SystemExit(2)


def cmd_summarize(args: argparse.Namespace) -> int:
    """``summarize``: per-span-path count/wall/bytes table + metrics."""
    tr = _load(args.trace)
    meta = tr["header"].get("meta", {})
    print(f"schema   {tr['header']['schema']}")
    if meta:
        print(f"meta     {json.dumps(meta, sort_keys=True)}")
    print(f"spans    {len(tr['spans'])}")
    print(f"events   {len(tr['events'])}")
    paths = span_paths(tr)
    if paths:
        t_by_path = {p: 0.0 for p in paths}
        by_id = {sp["id"]: sp for sp in tr["spans"]}
        for sp in tr["spans"]:
            parts = [sp["name"]]
            pid = sp.get("parent")
            while pid in by_id:
                parts.append(by_id[pid]["name"])
                pid = by_id[pid].get("parent")
            t_by_path["/".join(reversed(parts))] += sp["t1"] - sp["t0"]
        width = max(len(p) for p in paths)
        print(f"{'span path'.ljust(width)}  count     wall_s        bytes")
        for p in sorted(paths):
            s = paths[p]
            print(f"{p.ljust(width)}  {s['count']:5d}  {t_by_path[p]:9.4f}"
                  f"  {s['bytes']:11d}")
    snap = tr["metrics"].get("snapshot", {})
    for kind in ("counters", "gauges"):
        for name, v in sorted(snap.get(kind, {}).items()):
            print(f"{kind[:-1]}  {name} = {v}")
    unattr = tr["metrics"].get("unattributed", {})
    if any(unattr.values()):
        print(f"WARNING: unattributed ledger bytes: {unattr}")
    return 0


def cmd_export_chrome(args: argparse.Namespace) -> int:
    """``export-chrome``: trace -> Chrome trace-event JSON file."""
    tr = _load(args.trace)
    doc = to_chrome(tr)
    with open(args.out, "w") as f:
        json.dump(doc, f)
    print(f"{args.out}: {len(doc['traceEvents'])} events "
          f"(open in chrome://tracing or ui.perfetto.dev)")
    return 0


def _diff_dicts(label: str, a: Dict[str, Any], b: Dict[str, Any]) -> int:
    n = 0
    for key in sorted(set(a) | set(b)):
        va, vb = a.get(key), b.get(key)
        if va != vb:
            print(f"{label} {key}: {va} != {vb}")
            n += 1
    return n


def cmd_diff(args: argparse.Namespace) -> int:
    """``diff``: structural comparison of two traces (span paths, event
    counts, counters, unattributed bytes); exit 1 on any difference."""
    ta, tb = _load(args.a), _load(args.b)
    diffs = 0
    pa, pb = span_paths(ta), span_paths(tb)
    diffs += _diff_dicts("span", pa, pb)

    def ev_counts(tr: Dict[str, Any]) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in tr["events"]:
            out[ev["name"]] = out.get(ev["name"], 0) + 1
        return out

    diffs += _diff_dicts("events", ev_counts(ta), ev_counts(tb))
    diffs += _diff_dicts(
        "counter", ta["metrics"].get("snapshot", {}).get("counters", {}),
        tb["metrics"].get("snapshot", {}).get("counters", {}))
    diffs += _diff_dicts("unattributed",
                         ta["metrics"].get("unattributed", {}),
                         tb["metrics"].get("unattributed", {}))
    if diffs:
        print(f"{diffs} difference(s)")
        return 1
    print("traces structurally identical "
          f"({len(ta['spans'])} spans, {len(ta['events'])} events)")
    return 0


def cmd_regress(args: argparse.Namespace) -> int:
    """``regress``: gate BENCH_*.json scalars against the run history
    (``experiments/bench_history.jsonl``); exit 1 on any gated failure."""
    try:
        history = registry.load_history(args.history)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    files = args.bench or sorted(glob.glob("BENCH_*.json"))
    if not files:
        print("error: no BENCH_*.json files given or found", file=sys.stderr)
        return 2
    failed = False
    for path in files:
        name = registry.bench_name(path)
        if name is None:
            print(f"error: {path}: not a BENCH_*.json file", file=sys.stderr)
            return 2
        try:
            with open(path) as f:
                report = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: {path}: {e}", file=sys.stderr)
            return 2
        rep = registry.regress_report(name, report, history, k=args.k,
                                      min_history=args.min_history)
        verdict = "FAIL" if rep["failures"] else "ok"
        print(f"{name:12s} {verdict}  ({rep['checked']} scalars gated, "
              f"{rep['history_points']} history points)")
        for note in rep["notes"]:
            print(f"  note: {note}")
        for fail in rep["failures"]:
            print(f"  FAIL: {fail}")
        failed = failed or bool(rep["failures"])
    return 1 if failed else 0


def main(argv=None) -> int:
    """CLI dispatcher for ``python -m repro.obs`` subcommands."""
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("summarize", help="per-path span/byte/metric table")
    p.add_argument("trace")
    p.set_defaults(fn=cmd_summarize)
    p = sub.add_parser("export-chrome", help="Chrome trace-event JSON")
    p.add_argument("trace")
    p.add_argument("out")
    p.set_defaults(fn=cmd_export_chrome)
    p = sub.add_parser("diff", help="structural diff of two traces")
    p.add_argument("a")
    p.add_argument("b")
    p.set_defaults(fn=cmd_diff)
    p = sub.add_parser(
        "regress", help="gate BENCH_*.json files against the run history")
    p.add_argument("bench", nargs="*",
                   help="BENCH_*.json files (default: benchmarks/BENCH_*)")
    p.add_argument("--history",
                   default=os.path.join("experiments", "bench_history.jsonl"))
    p.add_argument("--k", type=float, default=4.0,
                   help="threshold half-width in MADs (default 4)")
    p.add_argument("--min-history", type=int, default=3,
                   help="history points required before a scalar is gated")
    p.set_defaults(fn=cmd_regress)
    try:
        args = ap.parse_args(argv)
    except SystemExit as e:
        raise SystemExit(2 if e.code not in (0, None) else 0)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
