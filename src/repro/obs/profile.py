"""repro.obs.profile — cost-annotated spans + the recompilation sentinel.

``profiled_jit`` wraps ``jax.jit`` for the runtime's compiled hot paths
(the §3.1 selection pipeline, the Pallas kernel entries, the stacked
LocalUpdate).  With a tracer active, every *new* call signature

  * bumps the ``compile.<name>`` / ``compile.<name>.<sig>`` counters in
    the tracer's ``MetricsRegistry`` and records a ``compile`` event
    under the open span — the **recompilation sentinel**: a
    retrace-per-round bug shows up as compile events parented to
    ``round > 0`` spans, which ``benchmarks/obs_bench.py`` asserts never
    happens (``zero_hot_path_recompiles_after_round_0``);
  * derives a :class:`CostRecord` from the compiled module's HLO text —
    ``launch/hlo_analysis.py`` is the repo's ONE FLOP/byte deriver and
    this module is its façade — and attaches ``flops``/``hbm_bytes``
    (plus the per-backend peaks, from which the closing span computes
    ``utilization``) to the enclosing ``kernel.*``/``select`` span.

With no tracer active (``FLConfig.observability`` off) the wrapper is a
plain ``jax.jit`` call behind one attribute read — bit-identical runs,
zero profiling work, exactly the NullTracer contract.

Import-safe without jax: jax, ``launch.hlo_analysis`` and ``launch.mesh``
are only imported lazily inside calls (the flcheck CI job imports
``repro.obs`` with no jax installed).
"""
from __future__ import annotations

import hashlib
import inspect
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

from repro.obs.tracer import get_tracer


# --------------------------------------------------------------------------
# the one cost record (façade over launch/hlo_analysis.parse_hlo)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class CostRecord:
    """Per-compiled-function cost, derived from post-SPMD HLO text.

    ``flops``/``hbm_bytes`` are while-loop-trip-expanded where XLA records
    ``known_trip_count`` (fori_loop); a *dynamic* while (the early-exit
    Lloyd loop) counts its body once and bumps ``unknown_trip_loops`` —
    the record is then a lower bound, flagged, never a guess."""
    flops: float = 0.0
    hbm_bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: float = 0.0
    unknown_trip_loops: int = 0

    def as_dict(self) -> Dict[str, float]:
        """Plain-dict form for span attrs / JSON reports."""
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "transcendentals": self.transcendentals,
                "collective_bytes": self.collective_bytes,
                "unknown_trip_loops": self.unknown_trip_loops}


def record_from_hlo(hc: Any) -> CostRecord:
    """``launch/hlo_analysis.HloCost`` -> :class:`CostRecord` — the one
    place the parser's fields are mapped into the record the rest of the
    repo consumes (dry-run keeps the parsed object for per-kind collective
    detail but takes its totals from here)."""
    return CostRecord(flops=hc.flops, hbm_bytes=hc.bytes,
                      transcendentals=hc.transcendentals,
                      collective_bytes=hc.collective_total,
                      unknown_trip_loops=hc.unknown_trips)


def cost_from_hlo_text(text: str) -> CostRecord:
    """The repo's single FLOP deriver: ``launch/hlo_analysis.parse_hlo``
    re-exposed as a :class:`CostRecord` (dry-run, roofline tables and the
    profiled spans all route through here)."""
    from repro.launch.hlo_analysis import parse_hlo
    return record_from_hlo(parse_hlo(text))


def cost_from_compiled(compiled: Any) -> CostRecord:
    """Cost of a ``jax`` AOT ``Compiled`` object (``jit.lower().compile()``)."""
    return cost_from_hlo_text(compiled.as_text())


def record_from_dryrun(rec: Dict[str, Any]) -> CostRecord:
    """Rebuild the cost record from a saved dry-run JSON (``launch/dryrun``
    output) so ``benchmarks/roofline_report.py`` renders from the same
    record type the live profiler attaches."""
    cost = rec.get("cost", {})
    coll = rec.get("collectives", {})
    return CostRecord(
        flops=float(cost.get("flops_expanded", cost.get("flops", 0.0))),
        hbm_bytes=float(cost.get("bytes_expanded",
                                 cost.get("bytes accessed", 0.0))),
        transcendentals=float(cost.get("transcendentals", 0.0)),
        collective_bytes=float(coll.get("total_bytes", 0.0)),
        unknown_trip_loops=int(coll.get("unknown_trip_counts", 0)))


# --------------------------------------------------------------------------
# per-backend peak table
# --------------------------------------------------------------------------
# Host-CPU peaks are order-of-magnitude estimates (a couple of AVX cores)
# — good enough for *relative* utilization trajectories on this container;
# the TPU entry is the v5e datasheet via launch/mesh.py (single source).
_CPU_PEAKS = {"peak_flops_bf16": 2.0e11, "peak_flops_f32": 1.0e11,
              "hbm_bw": 2.0e10, "ici_bw": 0.0}


def peak_table(backend: str) -> Dict[str, float]:
    """Peak FLOP/s and memory bandwidth for ``backend`` ('tpu'/'cpu'/...).
    The selection/kernels pipelines compute in f32, so span utilization
    uses ``peak_flops_f32``; the LM dry-run rooflines use bf16."""
    if backend == "tpu":
        from repro.launch import mesh
        return {"peak_flops_bf16": mesh.PEAK_FLOPS_BF16,
                "peak_flops_f32": mesh.PEAK_FLOPS_BF16 / 2,
                "hbm_bw": mesh.HBM_BW, "ici_bw": mesh.ICI_BW}
    return dict(_CPU_PEAKS)


def roofline(cost: CostRecord, peaks: Dict[str, float],
             dtype: str = "f32") -> Dict[str, Any]:
    """The three roofline terms + binding resource for one cost record —
    the single roofline calculator (dry-run reports and the selection
    bench both call this)."""
    peak = peaks[f"peak_flops_{dtype}"]
    compute_s = cost.flops / peak if peak else 0.0
    memory_s = cost.hbm_bytes / peaks["hbm_bw"] if peaks["hbm_bw"] else 0.0
    ici = peaks.get("ici_bw", 0.0)
    collective_s = cost.collective_bytes / ici if ici else 0.0
    bound = max((("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "bound": bound}


# --------------------------------------------------------------------------
# profiled_jit
# --------------------------------------------------------------------------
def _abstract(leaf: Any) -> str:
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        return f"{leaf.dtype}{tuple(leaf.shape)}"
    return f"{type(leaf).__name__}:{leaf!r}"


class ProfiledFunction:
    """``jax.jit`` plus the sentinel/cost layer.  Execution always goes
    through the one underlying jitted callable (so traced and untraced
    runs share jax's dispatch cache and stay bit-identical); profiling is
    bookkeeping around it, active only under a live tracer."""

    def __init__(self, fn: Callable, *, name: Optional[str] = None,
                 static_argnames: Tuple[str, ...] = ()) -> None:
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "fn")
        self.static_argnames = tuple(static_argnames)
        self.__doc__ = getattr(fn, "__doc__", None)
        self.__name__ = self.name
        self._jitted: Any = None
        self._pysig: Any = None
        self._costs: Dict[str, Optional[CostRecord]] = {}
        self._counted: set = set()

    # -- lazy jax plumbing -------------------------------------------
    def _jit(self) -> Any:
        if self._jitted is None:
            import jax
            self._jitted = jax.jit(self.fn,
                                   static_argnames=self.static_argnames)
        return self._jitted

    def signature_key(self, args: tuple, kwargs: dict) -> str:
        """Abstract call signature: (shape, dtype) per array leaf, repr
        for statics — mirrors jax's jit cache key closely enough that a
        new key here means jax compiled."""
        import jax
        if self._pysig is None:
            try:
                self._pysig = inspect.signature(self.fn)
            except (TypeError, ValueError):  # pragma: no cover
                self._pysig = False
        dyn, static = (args, dict(kwargs)), {}
        if self._pysig:
            try:
                bound = self._pysig.bind(*args, **kwargs)
                bound.apply_defaults()
                static = {k: v for k, v in bound.arguments.items()
                          if k in self.static_argnames}
                dyn = {k: v for k, v in bound.arguments.items()
                       if k not in self.static_argnames}
            except TypeError:
                pass
        leaves, treedef = jax.tree_util.tree_flatten(dyn)
        parts = [_abstract(l) for l in leaves]
        parts.append(str(treedef))
        parts.append(repr(sorted((k, repr(v)) for k, v in static.items())))
        return "|".join(parts)

    @staticmethod
    def _sig_hash(sig: str) -> str:
        return hashlib.md5(sig.encode()).hexdigest()[:10]

    def _derive_cost(self, sig: str, args: tuple,
                     kwargs: dict) -> Optional[CostRecord]:
        cost = self._costs.get(sig)
        if cost is not None or sig in self._costs:
            return cost
        try:
            compiled = self._jit().lower(*args, **kwargs).compile()
            cost = cost_from_compiled(compiled)
        except Exception:  # cost is telemetry; never fail the call for it
            cost = None
        self._costs[sig] = cost
        return cost

    def cost(self, *args: Any, **kwargs: Any) -> Optional[CostRecord]:
        """The :class:`CostRecord` this call signature would compile to
        (derives + caches on first use; no tracer required — benchmarks
        use this for their measured-FLOPs rows)."""
        return self._derive_cost(self.signature_key(args, kwargs),
                                 args, kwargs)

    # -- the call ----------------------------------------------------
    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        tracer = get_tracer()
        if not tracer.enabled:
            return self._jit()(*args, **kwargs)
        from repro.obs.tracer import _has_jax_tracer
        if _has_jax_tracer((args, kwargs)):
            # inside an enclosing trace: this call inlines into the outer
            # computation — its compile belongs to the outer function
            return self._jit()(*args, **kwargs)

        sig = self.signature_key(args, kwargs)
        if sig not in self._counted:
            # the sentinel: jax compiles exactly when it first sees this
            # signature, so count it where the trace can see the round
            self._counted.add(sig)
            h = self._sig_hash(sig)
            tracer.metrics.counter(f"compile.{self.name}").inc()
            tracer.metrics.counter(f"compile.{self.name}.{h}").inc()
            tracer.event("compile", fn=self.name, signature=h,
                         nth=len(self._counted))
        cost = self._derive_cost(sig, args, kwargs)
        out = self._jit()(*args, **kwargs)

        cur = tracer.current()
        if cur is not None and cost is not None:
            import jax
            peaks = peak_table(jax.default_backend())
            # accumulate: one span may cover several profiled calls
            # (chunked cohorts); the span computes utilization on close
            cur.attrs["flops"] = cur.attrs.get("flops", 0.0) + cost.flops
            cur.attrs["hbm_bytes"] = (cur.attrs.get("hbm_bytes", 0.0)
                                      + cost.hbm_bytes)
            cur.attrs.setdefault("peak_flops", peaks["peak_flops_f32"])
            cur.attrs.setdefault("peak_hbm_bw", peaks["hbm_bw"])
            if cost.unknown_trip_loops:
                cur.attrs["cost_is_lower_bound"] = True
        return out


def profiled_jit(fn: Optional[Callable] = None, *,
                 name: Optional[str] = None,
                 static_argnames: Tuple[str, ...] = ()) -> Any:
    """Decorator/factory: ``jax.jit`` with the sentinel + cost layer.

    Use exactly like ``functools.partial(jax.jit, static_argnames=...)``::

        @profiled_jit(static_argnames=("k",))
        def kmeans(x, k, ...): ...

    or inline: ``prof = profiled_jit(kernel_fn, name="lloyd",
    static_argnames=("block_n", "interpret"))``."""
    if fn is None:
        return lambda f: ProfiledFunction(f, name=name,
                                          static_argnames=static_argnames)
    return ProfiledFunction(fn, name=name, static_argnames=static_argnames)
