"""repro.obs.timing — the one wall-clock for the whole repo.

Every benchmark and runtime phase measurement goes through ``monotonic``
(a monotonic high-resolution counter; ``time.time()`` is wall-clock and
can step backwards under NTP) or ``timeit`` (warmup-aware, device-sync
aware). flcheck rule OBS001 enforces that no other module reads
``time.time``/``perf_counter`` directly.

Import-safe without jax: the analysis CI job runs ``python -m
repro.analysis`` with no jax installed, and that path imports this
module. jax is only touched lazily inside ``sync``/``timeit``.
"""
from __future__ import annotations

import time as _time
from typing import Any, Callable, NamedTuple

# flcheck: disable=OBS001 (this module IS the sanctioned clock)
monotonic: Callable[[], float] = _time.perf_counter


def sync(x: Any) -> Any:
    """Block until device work backing ``x`` is done (identity without
    jax, on None, or on abstract tracers during jit tracing)."""
    if x is None:
        return x
    try:
        import jax
    except ImportError:  # pragma: no cover - analysis-only environment
        return x
    if any(isinstance(l, jax.core.Tracer) for l in jax.tree_util.tree_leaves(x)):
        return x
    return jax.block_until_ready(x)


class Timing(NamedTuple):
    """Result of ``timeit``: seconds per call + the (synced) last output."""
    seconds: float
    out: Any


def timeit(fn: Callable[..., Any], *args: Any, iters: int = 5,
           warmup: int = 1, reduce: str = "mean", **kwargs: Any) -> Timing:
    """Warmup-aware timer: run ``fn(*args, **kwargs)`` ``warmup`` times
    (compile/caches), then time ``iters`` calls, blocking on the output
    each iteration so async device dispatch is not under-counted.

    ``reduce`` is ``"mean"`` (default, matches the kernel benches) or
    ``"min"`` (best-of, noise-robust, matches the selection bench).
    """
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    out = None
    for _ in range(warmup):
        out = sync(fn(*args, **kwargs))
    samples = []
    for _ in range(iters):
        t0 = monotonic()
        out = sync(fn(*args, **kwargs))
        samples.append(monotonic() - t0)
    if reduce == "mean":
        return Timing(sum(samples) / len(samples), out)
    if reduce == "min":
        return Timing(min(samples), out)
    raise ValueError(f"unknown reduce {reduce!r} (want 'mean' or 'min')")
