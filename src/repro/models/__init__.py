from repro.models.registry import make_lm, make_split_model, count_params
from repro.models.wrn import make_split_wrn, init_wrn, wrn_apply

__all__ = ["make_lm", "make_split_model", "count_params", "make_split_wrn",
           "init_wrn", "wrn_apply"]
