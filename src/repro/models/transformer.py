"""Config-driven LM assembly for all assigned architectures.

A model is a list of STAGES. Each stage is either
  * scan:   a repeating unit of block specs, params stacked over repeats
            (lax.scan keeps HLO small for 34-72 layer models), or
  * unroll: explicit layers (pattern prefixes/remainders, e.g. deepseek's
            first dense layer, gemma3's 34 = 5x(5L+1G) + 4L tail).

The paper's split at layer j slices the stage list (unit-aligned), giving the
lower/upper param partition used by FedAvg / MetaTraining (core.split).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

PyTree = Any


# --------------------------------------------------------------------------
# block specs & stage decomposition
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class BlockSpec:
    mixer: str                  # attn | mla | mamba | rwkv | attn_cross
    ffn: str                    # dense | moe | rwkv_ffn
    window: int = 0             # static sliding window (0 = full)
    causal: bool = True


@dataclass(frozen=True)
class Stage:
    kind: str                   # scan | unroll
    unit: Tuple[BlockSpec, ...]
    repeats: int


def layer_specs(cfg: ModelConfig, force_swa: bool = False,
                decoder: bool = True) -> List[BlockSpec]:
    """Per-layer block specs for the decoder stack (or encoder if decoder=False)."""
    if not decoder:  # whisper encoder: bidirectional attention + dense FFN
        return [BlockSpec("attn", "dense", 0, causal=False)] * cfg.encoder_layers
    kinds = cfg.layer_kinds()
    windows = cfg.window_sizes(0, force_swa)
    specs, ai = [], 0
    for i, kind in enumerate(kinds):
        if kind == "rwkv":
            mixer, w = "rwkv", 0
        elif kind == "mamba":
            mixer, w = "mamba", 0
        else:
            mixer = "mla" if cfg.attention_kind == "mla" else "attn"
            if cfg.is_encoder_decoder:
                mixer = "attn_cross"
            w = windows[ai]
            ai += 1
        if kind == "rwkv":
            ffn = "rwkv_ffn"
        elif cfg.is_moe and i >= cfg.first_dense_layers \
                and (i % cfg.moe_layer_period == cfg.moe_layer_period - 1
                     or cfg.moe_layer_period == 1):
            ffn = "moe"
        else:
            ffn = "dense"
        specs.append(BlockSpec(mixer, ffn, w))
    return specs


def decompose(specs: List[BlockSpec], boundary: Optional[int] = None
              ) -> List[Stage]:
    """Group per-layer specs into scan/unroll stages. ``boundary`` forces a
    stage break at layer index j (the paper's split point)."""
    if boundary is not None and 0 < boundary < len(specs):
        return decompose(specs[:boundary]) + decompose(specs[boundary:])
    n = len(specs)
    if n == 0:
        return []
    best = None  # (scanned_layers, prefix, period, repeats)
    for prefix in range(0, min(3, n)):
        for p in range(1, min(9, n - prefix + 1)):
            reps = (n - prefix) // p
            if reps < 2:
                continue
            body = specs[prefix:prefix + reps * p]
            if all(body[i] == body[i % p] for i in range(len(body))):
                score = reps * p
                if best is None or score > best[0] or (
                        score == best[0] and p < best[2]):
                    best = (score, prefix, p, reps)
    if best is None:
        return [Stage("unroll", tuple(specs), 1)]
    _, prefix, p, reps = best
    stages = []
    if prefix:
        stages.append(Stage("unroll", tuple(specs[:prefix]), 1))
    stages.append(Stage("scan", tuple(specs[prefix:prefix + p]), reps))
    rest = specs[prefix + reps * p:]
    if rest:
        stages.append(Stage("unroll", tuple(rest), 1))
    return stages


def stage_layers(st: Stage) -> int:
    return len(st.unit) * st.repeats


# --------------------------------------------------------------------------
# per-block init/apply/cache dispatch
# --------------------------------------------------------------------------
def _block_init(key, cfg: ModelConfig, spec: BlockSpec) -> PyTree:
    k1, k2 = jax.random.split(key)
    if spec.mixer in ("attn", "attn_cross"):
        mixer = L.attn_init(k1, cfg, cross=(spec.mixer == "attn_cross"))
    elif spec.mixer == "mla":
        mixer = L.mla_init(k1, cfg)
    elif spec.mixer == "mamba":
        mixer = L.mamba_init(k1, cfg)
    elif spec.mixer == "rwkv":
        mixer = L.rwkv_init(k1, cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "dense":
        ffn = L.ffn_init(k2, cfg)
    elif spec.ffn == "moe":
        ffn = L.moe_init(k2, cfg)
    elif spec.ffn == "rwkv_ffn":
        ffn = L.rwkv_ffn_init(k2, cfg)
    else:
        raise ValueError(spec.ffn)
    return {"mixer": mixer, "ffn": ffn}


def _block_cache(cfg: ModelConfig, spec: BlockSpec, batch: int, seq_len: int,
                 dtype) -> PyTree:
    c: dict = {}
    if spec.mixer in ("attn", "attn_cross"):
        c["mixer"] = L.attn_cache_init(cfg, batch, seq_len, spec.window, dtype)
    elif spec.mixer == "mla":
        c["mixer"] = L.mla_cache_init(cfg, batch, seq_len, dtype)
    elif spec.mixer == "mamba":
        c["mixer"] = L.mamba_cache_init(cfg, batch)
    elif spec.mixer == "rwkv":
        c["mixer"] = L.rwkv_cache_init(cfg, batch)
        c["ffn_x_prev"] = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return c


def _block_apply(params, x, spec: BlockSpec, cfg: ModelConfig, mode: str,
                 cache, pos, enc_out):
    kw = dict(cfg=cfg, mode=mode, cache=(cache or {}).get("mixer"), pos=pos,
              window=spec.window)
    if spec.mixer in ("attn", "attn_cross"):
        y, mc = L.attn_apply(params["mixer"], x, causal=spec.causal,
                             enc_out=enc_out if spec.mixer == "attn_cross"
                             else None, **kw)
    elif spec.mixer == "mla":
        y, mc = L.mla_apply(params["mixer"], x, absorbed=cfg.mla_absorbed, **kw)
    elif spec.mixer == "mamba":
        y, mc = L.mamba_apply(params["mixer"], x, **kw)
    else:
        y, mc = L.rwkv_apply(params["mixer"], x, **kw)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    new_cache = {"mixer": mc} if mc is not None else {}
    if spec.ffn == "dense":
        x = x + L.ffn_apply(params["ffn"], x, cfg=cfg)
    elif spec.ffn == "moe":
        y, aux = L.moe_apply(params["ffn"], x, cfg=cfg)
        x = x + y
    else:  # rwkv_ffn
        xp = (cache or {}).get("ffn_x_prev") if mode == "decode" else None
        y, xn_last = L.rwkv_ffn_apply(params["ffn"], x, cfg=cfg, x_prev=xp)
        x = x + y
        if mode == "decode":
            new_cache["ffn_x_prev"] = xn_last.astype(jnp.float32)
    return x, new_cache, aux


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------
def sinusoidal_pos(d: int, positions) -> jnp.ndarray:
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


class LM:
    """Bundles init/apply/cache/split for one ModelConfig."""

    def __init__(self, cfg: ModelConfig, force_swa: bool = False,
                 remat: bool = False, remat_policy=None,
                 act_spec=None):
        self.cfg = cfg
        self.force_swa = force_swa
        self.remat = remat
        self.remat_policy = remat_policy    # None = recompute everything
        # optional PartitionSpec pinned onto the hidden states between blocks
        # (sequence sharding for archs whose heads don't divide the model
        # axis — EXPERIMENTS.md §Perf H1); applied in full mode only.
        self.act_spec = act_spec
        self.specs = layer_specs(cfg, force_swa)
        self.stages = decompose(self.specs)
        if cfg.is_encoder_decoder:
            self.enc_specs = layer_specs(cfg, decoder=False)
            self.enc_stages = decompose(self.enc_specs)

    # ---------------- init ----------------
    def _stage_init(self, key, stage: Stage) -> PyTree:
        if stage.kind == "unroll":
            keys = jax.random.split(key, len(stage.unit))
            return [_block_init(k, self.cfg, s)
                    for k, s in zip(keys, stage.unit)]
        # scan: params stacked over repeats per unit position
        keys = jax.random.split(key, stage.repeats * len(stage.unit)
                                ).reshape(stage.repeats, len(stage.unit), 2)
        out = []
        for u, spec in enumerate(stage.unit):
            stacked = jax.vmap(lambda k: _block_init(k, self.cfg, spec)
                               )(keys[:, u])
            out.append(stacked)
        return out

    def init(self, key) -> PyTree:
        cfg = self.cfg
        ks = L.keygen(key)
        v, d = cfg.padded_vocab, cfg.d_model
        params: dict = {
            "embed": (jax.random.normal(next(ks), (v, d)) / math.sqrt(d)
                      ).astype(jnp.float32),
            "final_norm": jnp.ones((d,)),
            "stages": [self._stage_init(next(ks), st) for st in self.stages],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(next(ks), (d, v))
        if cfg.is_encoder_decoder:
            params["enc_stages"] = [self._stage_init(next(ks), st)
                                    for st in self.enc_stages]
            params["enc_norm"] = jnp.ones((d,))
        if cfg.frontend == "vision_stub":
            # projector from (stubbed) vision embeddings into d_model
            params["proj"] = L.dense_init(next(ks), (d, d))
        return params

    # ---------------- cache ----------------
    def init_cache(self, batch: int, seq_len: int,
                   dtype=jnp.bfloat16) -> PyTree:
        def stage_cache(st: Stage):
            if st.kind == "unroll":
                return [_block_cache(self.cfg, s, batch, seq_len, dtype)
                        for s in st.unit]
            out = []
            for spec in st.unit:
                one = _block_cache(self.cfg, spec, batch, seq_len, dtype)
                out.append(jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (st.repeats,) + x.shape).copy(), one))
            return out
        cache: dict = {"stages": [stage_cache(st) for st in self.stages],
                       "pos": jnp.zeros((batch,), jnp.int32)}
        if self.cfg.is_encoder_decoder:
            cache["enc_out"] = jnp.zeros(
                (batch, self.cfg.encoder_seq_len, self.cfg.d_model), dtype)
        return cache

    # ---------------- apply ----------------
    def _constrain(self, x, mode):
        if self.act_spec is not None and mode != "decode" and x.ndim == 3:
            x = jax.lax.with_sharding_constraint(x, self.act_spec)
        return x

    def _run_stages(self, stages, stage_params, x, mode, cache_stages, pos,
                    enc_out):
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        x = self._constrain(x, mode)
        for si, (st, sp) in enumerate(zip(stages, stage_params)):
            scache = cache_stages[si] if cache_stages is not None else None
            if st.kind == "unroll":
                ncs = []
                for li, spec in enumerate(st.unit):
                    c = scache[li] if scache is not None else None
                    x, nc, aux = _block_apply(sp[li], x, spec, self.cfg, mode,
                                              c, pos, enc_out)
                    aux_total += aux
                    ncs.append(nc)
                new_caches.append(ncs)
            else:
                def body(carry, xs):
                    h, auxc = carry
                    lp, lc = xs
                    ncs_u = []
                    h = self._constrain(h, mode)
                    for ui, spec in enumerate(st.unit):
                        c = lc[ui] if lc is not None else None
                        h, nc, aux = _block_apply(lp[ui], h, spec, self.cfg,
                                                  mode, c, pos, enc_out)
                        auxc += aux
                        ncs_u.append(nc)
                    return (h, auxc), ncs_u

                if scache is None:
                    # no cache: scan over params only
                    def body_nc(carry, lp):
                        return body(carry, (lp, [None] * len(st.unit)))[0], None
                    if self.remat:
                        body_nc = jax.checkpoint(
                            body_nc, policy=self.remat_policy)
                    (x, aux_total), _ = jax.lax.scan(body_nc, (x, aux_total), sp)
                    new_caches.append(None)
                else:
                    (x, aux_total), ncs = jax.lax.scan(
                        body, (x, aux_total), (sp, scache))
                    new_caches.append(ncs)
        return x, aux_total, new_caches

    def encode(self, params, frames):
        """Whisper encoder over stubbed frame embeddings (B, enc_len, d)."""
        pos = jnp.arange(frames.shape[1])
        h = frames + sinusoidal_pos(self.cfg.d_model, pos)[None].astype(frames.dtype)
        h, _, _ = self._run_stages(self.enc_stages, params["enc_stages"], h,
                                   "full", None, None, None)
        return L.rms_norm(h, params["enc_norm"], self.cfg.norm_eps)

    def embed_tokens(self, params, tokens):
        e = params["embed"][tokens] * math.sqrt(self.cfg.d_model)
        return e

    def apply(self, params, tokens, *, mode: str = "full", cache=None,
              prefix_embeds=None, enc_frames=None, return_hidden: bool = False,
              stage_range: Optional[Tuple[int, int]] = None,
              hidden_in=None, dtype=jnp.float32):
        """Forward. mode: full (train/prefill) | decode (1 token + cache).
        stage_range selects a sub-interval of stages (the paper's lower/upper
        application); hidden_in feeds activations at a stage boundary."""
        cfg = self.cfg
        # mixed precision: master params stay f32 outside; compute in `dtype`
        # (grads flow through the casts, so the optimizer sees f32 grads)
        if dtype != jnp.float32:
            params = jax.tree.map(
                lambda x: x.astype(dtype)
                if (hasattr(x, "dtype") and x.dtype == jnp.float32) else x,
                params)
        enc_out = None
        if cfg.is_encoder_decoder:
            if mode == "decode":
                enc_out = cache["enc_out"]
            else:
                assert enc_frames is not None
                enc_out = self.encode(params, enc_frames.astype(dtype))

        n_stages = len(self.stages)
        lo, hi = stage_range if stage_range is not None else (0, n_stages)

        if hidden_in is not None:
            h = hidden_in
            pos = cache["pos"] if cache is not None else None
        elif mode == "decode":
            pos = cache["pos"]
            h = self.embed_tokens(params, tokens).astype(dtype)
            if cfg.rope_theta == 0 and (cfg.is_encoder_decoder):
                h = h + sinusoidal_pos(cfg.d_model, pos[:, None]).astype(dtype)
        else:
            pos = None
            h = self.embed_tokens(params, tokens).astype(dtype)
            if cfg.rope_theta == 0 and cfg.is_encoder_decoder:
                h = h + sinusoidal_pos(
                    cfg.d_model, jnp.arange(tokens.shape[1]))[None].astype(dtype)
            if prefix_embeds is not None:       # VLM: prepend patch embeddings
                pe = (prefix_embeds.astype(dtype) @ params["proj"].astype(dtype))
                h = jnp.concatenate([pe, h], axis=1)

        cache_stages = cache["stages"][lo:hi] if cache is not None else None
        h, aux, new_stage_caches = self._run_stages(
            self.stages[lo:hi], params["stages"][lo:hi], h, mode,
            cache_stages, pos, enc_out)

        new_cache = None
        if cache is not None:
            new_cache = dict(cache)
            new_cache["stages"] = (cache["stages"][:lo] + new_stage_caches
                                   + cache["stages"][hi:])
            if hi == n_stages:
                new_cache["pos"] = cache["pos"] + 1
        if hi < n_stages or return_hidden:
            return h, new_cache, aux

        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = h @ params["embed"].T.astype(h.dtype)
        else:
            logits = h @ params["lm_head"].astype(h.dtype)
        return logits, new_cache, aux

    # ---------------- losses ----------------
    def loss(self, params, batch, dtype=jnp.float32):
        """Next-token CE. batch = (tokens, labels_unused) or dict with
        prefix_embeds / enc_frames for vlm/audio."""
        tokens, extras = self._unpack(batch)
        logits, _, aux = self.apply(params, tokens, mode="full",
                                    dtype=dtype, **extras)
        # align: with prefix tokens, predictions for text start after prefix
        p = self.cfg.num_prefix_tokens if extras.get("prefix_embeds") is not None else 0
        logits = logits[:, p:, :]
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], -1)[..., 0]
        return nll.mean() + aux

    def _unpack(self, batch):
        if isinstance(batch, dict):
            tokens = batch["tokens"]
            extras = {k: batch[k] for k in ("prefix_embeds", "enc_frames")
                      if k in batch}
            return tokens, extras
        tokens = batch[0] if isinstance(batch, (tuple, list)) else batch
        return tokens, {}


# --------------------------------------------------------------------------
# the paper's SplitModel view over an LM
# --------------------------------------------------------------------------
def make_split_lm(cfg: ModelConfig, split_layer: Optional[int] = None,
                  dtype=jnp.float32):
    """SplitModel for a decoder LM: lower = embed + stages[:b], upper =
    stages[b:] + final norm + head. The split layer is rounded to the nearest
    stage-unit boundary (the paper also splits at a group boundary)."""
    from repro.core.split import SplitModel

    j = split_layer if split_layer is not None else cfg.split_layer
    specs = layer_specs(cfg)
    # round j to a boundary compatible with stage decomposition
    base = decompose(specs, boundary=j)
    lm = LM(cfg)
    lm.stages = base                      # stage list with a break at j
    boundary_stage = 0
    acc = 0
    for si, st in enumerate(base):
        if acc >= j:
            boundary_stage = si
            break
        acc += stage_layers(st)
    else:
        boundary_stage = len(base) - 1

    def split(params):
        lower = {"embed": params["embed"],
                 "stages": params["stages"][:boundary_stage]}
        if "proj" in params:
            lower["proj"] = params["proj"]
        upper = {"stages": params["stages"][boundary_stage:],
                 "final_norm": params["final_norm"]}
        if "lm_head" in params:
            upper["lm_head"] = params["lm_head"]
        if cfg.tie_embeddings:
            upper["embed_head"] = params["embed"]
        return lower, upper

    def merge(lower, upper):
        p = {"embed": lower["embed"],
             "stages": list(lower["stages"]) + list(upper["stages"]),
             "final_norm": upper["final_norm"]}
        if "lm_head" in upper:
            p["lm_head"] = upper["lm_head"]
        if "proj" in lower:
            p["proj"] = lower["proj"]
        return p

    def apply_lower(params_full, tokens):
        h, _, _ = lm.apply(params_full, tokens, mode="full",
                           stage_range=(0, boundary_stage), dtype=dtype)
        return h

    def apply_upper_from(upper, acts):
        # rebuild a params view the LM understands
        p = {"stages": [None] * boundary_stage + list(upper["stages"]),
             "final_norm": upper["final_norm"],
             "embed": upper.get("embed_head")}
        if "lm_head" in upper:
            p["lm_head"] = upper["lm_head"]
        h, _, aux = lm.apply(p, None, mode="full", hidden_in=acts,
                             stage_range=(boundary_stage, len(base)),
                             dtype=dtype)
        return h, aux

    def apply_upper(params_full, acts):
        _, upper = split(params_full)
        logits, _ = apply_upper_from(upper, acts)
        return logits

    def full_apply(params, tokens):
        logits, _, _ = lm.apply(params, tokens, mode="full", dtype=dtype)
        return logits

    def loss(params, batch):
        return lm.loss(params, batch, dtype=dtype)

    def upper_loss(upper, acts, targets):
        logits, aux = apply_upper_from(upper, acts)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, targets[:, 1:][..., None], -1)[..., 0]
        return nll.mean(-1) + aux             # per-sample
    return SplitModel(
        config=cfg, split_layer=j, init=lm.init, apply=full_apply,
        apply_lower=apply_lower, apply_upper=apply_upper, split=split,
        merge=merge, loss=loss, upper_loss=upper_loss), lm
