"""Wide Residual Network (Zagoruyko & Komodakis) — the paper's global model.

WRN-40-1: conv3x3(16) -> group1(16) -> group2(32,/2) -> group3(64,/2)
          -> norm+relu -> global avg pool -> fc(10)
(40-4)/6 = 6 basic blocks per group; paper splits after group 1, giving
activation maps of 16 channels x 32 x 32 (§4.1).

Normalization: BatchNorm with *batch statistics in both train and eval*
(no running-stat aggregation — the standard choice in FL, where averaging
client running stats is its own research problem; recorded in DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.wrn_cifar import WRNConfig
from repro.models.layers import keygen

PyTree = Any


def conv_init(key, kh, kw, cin, cout):
    scale = math.sqrt(2.0 / (kh * kw * cin))
    return jax.random.normal(key, (kh, kw, cin, cout)) * scale


def conv(x, w, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batch_norm(x, scale, bias, eps=1e-5):
    mean = x.mean((0, 1, 2))
    var = x.var((0, 1, 2))
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def block_init(key, cin, cout):
    ks = keygen(key)
    p = {"bn1": _bn_init(cin), "conv1": conv_init(next(ks), 3, 3, cin, cout),
         "bn2": _bn_init(cout), "conv2": conv_init(next(ks), 3, 3, cout, cout)}
    if cin != cout:
        p["shortcut"] = conv_init(next(ks), 1, 1, cin, cout)
    return p


def block_apply(p, x, stride: int):
    h = jax.nn.relu(batch_norm(x, **p["bn1"]))
    sc = conv(h, p["shortcut"], stride) if "shortcut" in p else x
    h = conv(h, p["conv1"], stride)
    h = jax.nn.relu(batch_norm(h, **p["bn2"]))
    h = conv(h, p["conv2"], 1)
    return h + sc


def init_wrn(cfg: WRNConfig, key) -> PyTree:
    ks = keygen(key)
    n = cfg.blocks_per_group
    widths = [16, 16 * cfg.widen, 32 * cfg.widen, 64 * cfg.widen]
    params: dict = {"conv_in": conv_init(next(ks), 3, 3, cfg.channels, widths[0])}
    for g in range(3):
        cin = widths[g]
        cout = widths[g + 1]
        blocks = [block_init(next(ks), cin if b == 0 else cout, cout)
                  for b in range(n)]
        params[f"group{g + 1}"] = blocks
    params["bn_out"] = _bn_init(widths[3])
    params["fc_w"] = jax.random.normal(next(ks), (widths[3], cfg.num_classes)) \
        / math.sqrt(widths[3])
    params["fc_b"] = jnp.zeros((cfg.num_classes,))
    return params


def group_apply(blocks, x, stride: int):
    for b, p in enumerate(blocks):
        x = block_apply(p, x, stride if b == 0 else 1)
    return x


def wrn_lower(cfg: WRNConfig, params, x):
    """conv_in + groups up to split_group -> the paper's activation maps."""
    h = conv(x, params["conv_in"], 1)
    for g in range(1, cfg.split_group + 1):
        h = group_apply(params[f"group{g}"], h, 1 if g == 1 else 2)
    return h


def wrn_upper(cfg: WRNConfig, params, acts):
    h = acts
    for g in range(cfg.split_group + 1, 4):
        h = group_apply(params[f"group{g}"], h, 2)
    h = jax.nn.relu(batch_norm(h, **params["bn_out"]))
    h = h.mean((1, 2))
    return h @ params["fc_w"] + params["fc_b"]


def wrn_apply(cfg: WRNConfig, params, x):
    return wrn_upper(cfg, params, wrn_lower(cfg, params, x))


def make_split_wrn(cfg: WRNConfig):
    """SplitModel view (core.split) of the WRN at the paper's split point."""
    from repro.core.split import SplitModel

    lower_keys = ["conv_in"] + [f"group{g}" for g in range(1, cfg.split_group + 1)]

    def split(params):
        lower = {k: params[k] for k in lower_keys}
        upper = {k: v for k, v in params.items() if k not in lower_keys}
        return lower, upper

    def merge(lower, upper):
        return {**lower, **upper}

    def loss(params, batch):
        x, y = batch
        logits = wrn_apply(cfg, params, x)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return -jnp.take_along_axis(lp, y[:, None], -1).mean()

    def upper_loss(upper_params, acts, targets):
        # upper params may lack lower keys; wrn_upper only touches upper ones
        logits = wrn_upper(cfg, upper_params, acts)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return -jnp.take_along_axis(lp, targets[:, None], -1)[:, 0]  # per-sample

    return SplitModel(
        config=cfg, split_layer=cfg.split_group,
        init=lambda key: init_wrn(cfg, key),
        apply=lambda p, x: wrn_apply(cfg, p, x),
        apply_lower=lambda p, x: wrn_lower(cfg, p, x),
        apply_upper=lambda p, a: wrn_upper(cfg, p, a),
        split=split, merge=merge, loss=loss, upper_loss=upper_loss)
