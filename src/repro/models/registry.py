"""Model registry + analytic parameter counts (via jax.eval_shape — zero
allocation, always exact w.r.t. the real init)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig


def make_lm(cfg: ModelConfig, force_swa: bool = False):
    from repro.models.transformer import LM
    return LM(cfg, force_swa=force_swa)


def make_split_model(cfg_or_id, split_layer: Optional[int] = None):
    from repro.configs import get_config
    from repro.models.transformer import make_split_lm
    cfg = get_config(cfg_or_id) if isinstance(cfg_or_id, str) else cfg_or_id
    return make_split_lm(cfg, split_layer)


_EXPERT_KEYS = ("we_gate", "we_up", "we_down")


@functools.lru_cache(maxsize=64)
def _param_shapes(cfg: ModelConfig):
    lm = make_lm(cfg)
    shapes = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((keys, tuple(leaf.shape)))
    return out


def count_params(cfg: ModelConfig, active_only: bool = False,
                 include_embed: bool = True) -> int:
    total = 0.0
    frac = (cfg.num_experts_per_tok / cfg.num_experts) if cfg.is_moe else 1.0
    for keys, shape in _param_shapes(cfg):
        n = float(np.prod(shape)) if shape else 1.0
        if not include_embed and ("embed" in keys or "lm_head" in keys):
            continue
        if active_only and any(k in keys for k in _EXPERT_KEYS):
            n *= frac
        total += n
    return int(total)
