"""Layer zoo for the assigned architectures.

Conventions:
  * params are plain dicts of jnp arrays; layer weights get stacked along a
    leading repeat axis by the transformer assembler (lax.scan).
  * every mixer has the signature
        apply(params, x, *, cfg, mode, cache, pos, window) -> (y, new_cache)
    mode in {"full", "decode"}; "full" covers train & prefill (causal);
    "decode" consumes ONE new token against the cache.
  * attention caches are ring buffers of size ``min(window or S, S)`` so
    sliding-window layers hold O(window) state at 500k context (keys stored
    post-RoPE, so ring order is irrelevant to the softmax).
  * chunked (online-softmax) attention is the pure-jnp reference of the
    Pallas flash kernel and keeps prefill memory sub-quadratic.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def keygen(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


# --------------------------------------------------------------------------
# norms & activations
# --------------------------------------------------------------------------
def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, D/2)
    if ang.ndim == 2:
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.stack([y1, y2], -1).reshape(x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# scaled-dot-product cores (reference paths; Pallas kernels mirror these)
# --------------------------------------------------------------------------
NEG = -1e30


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def sdpa_full(q, k, v, *, causal: bool, window: int, q_offset: int = 0):
    """Direct attention (small seq). q:(B,Sq,H,D) k,v:(B,Sk,KV,D)."""
    h, kv = q.shape[2], k.shape[2]
    k, v = _repeat_kv(k, h // kv), _repeat_kv(v, h // kv)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(q.shape[1])[:, None] + q_offset
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(s, bool)
    if causal:
        mask &= (qi >= ki)[None, None]
    if window > 0:
        mask &= (qi - ki < window)[None, None]
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, -1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def sdpa_chunked(q, k, v, *, causal: bool, window: int, chunk: int = 1024):
    """Flash attention with a CUSTOM VJP: the backward pass recomputes the
    score chunks instead of letting autodiff store per-chunk f32 residuals
    through the scan (which costs O(S^2) f32 HBM traffic — EXPERIMENTS.md
    §Perf H1 iteration 4). Mirrors what kernels/flash_attention.py does in
    VMEM on TPU. ~2x less attention HBM traffic in training."""
    return _sdpa_flash(q, k, v, causal, window, chunk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _sdpa_flash(q, k, v, causal, window, chunk):
    out, _, _ = _flash_fwd_inner(q, k, v, causal, window, chunk)
    return out


def _flash_fwd_inner(q, k, v, causal, window, chunk):
    out, m, l = _sdpa_chunked_raw(q, k, v, causal=causal, window=window,
                                  chunk=chunk, return_stats=True)
    return out, m, l


def _sdpa_flash_fwd(q, k, v, causal, window, chunk):
    out, m, l = _flash_fwd_inner(q, k, v, causal, window, chunk)
    return out, (q, k, v, out, m, l)


def _sdpa_flash_bwd(causal, window, chunk, res, dout):
    q, k, v, out, m, l = res
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    n_rep = h // kv
    scale = 1.0 / math.sqrt(d)
    nchunks = (sk + chunk - 1) // chunk
    pad = nchunks * chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    kc = kp.reshape(b, nchunks, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nchunks, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    qi = jnp.arange(sq)[:, None]
    # D_i = rowsum(dout * out) (the softmax-jacobian diagonal term)
    D = jnp.einsum("bqhd,bqhd->bhq", dout.astype(jnp.float32),
                   out.astype(jnp.float32))
    li = jnp.maximum(l, 1e-30)

    def body(dq_acc, xs):
        ci, kcur, vcur = xs
        kr = _repeat_kv(kcur, n_rep)
        vr = _repeat_kv(vcur, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * scale
        ki = ci * chunk + jnp.arange(chunk)[None, :]
        mask = ki < sk
        if causal:
            mask &= qi >= ki
        if window > 0:
            mask &= (qi - ki) < window
        s = jnp.where(mask[None, None], s, NEG)
        p = jnp.exp(s - m[..., None]) / li[..., None]          # true probs
        dp = jnp.einsum("bqhd,bkhd->bhqk", dout, vr).astype(jnp.float32)
        ds = p * (dp - D[..., None]) * scale
        ds16 = ds.astype(q.dtype)
        p16 = p.astype(q.dtype)
        dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds16, kr
                                     ).astype(jnp.float32)
        dk_f = jnp.einsum("bhqk,bqhd->bkhd", ds16, q)          # (b,chunk,h,d)
        dv_f = jnp.einsum("bhqk,bqhd->bkhd", p16, dout)
        # fold GQA reps back onto kv heads
        dk_c = dk_f.reshape(b, chunk, kv, n_rep, d).sum(3)
        dv_c = dv_f.reshape(b, chunk, kv, n_rep, d).sum(3)
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros((b, sq, h, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (jnp.arange(nchunks), kc, vc))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, kv, d)[:, :sk]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, kv, d)[:, :sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_sdpa_flash.defvjp(_sdpa_flash_fwd, _sdpa_flash_bwd)


def _sdpa_chunked_raw(q, k, v, *, causal: bool, window: int,
                      chunk: int = 1024, return_stats: bool = False):
    """Online-softmax attention, scanning KV chunks: O(S*chunk) live memory.
    This is the jnp oracle of kernels/flash_attention.py."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    n_rep = h // kv
    scale = 1.0 / math.sqrt(d)
    nchunks = (sk + chunk - 1) // chunk
    pad = nchunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    qi = jnp.arange(sq)[:, None]

    def body(carry, xs):
        acc, m, l = carry                     # (B,Sq,H,D), (B,H,Sq), (B,H,Sq)
        ki_chunk, kcur, vcur = xs
        kcur = _repeat_kv(kcur, n_rep)
        vcur = _repeat_kv(vcur, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kcur).astype(jnp.float32) * scale
        ki = ki_chunk * chunk + jnp.arange(chunk)[None, :]
        mask = ki < sk
        if causal:
            mask &= qi >= ki
        if window > 0:
            mask &= (qi - ki) < window
        s = jnp.where(mask[None, None], s, NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        # probabilities stored/multiplied in the input dtype (flash-kernel
        # convention): for bf16 models p in [0,1] is safe in bf16 and halves
        # the dominant (B,H,Sq,chunk) HBM traffic of the reference path
        p16 = p.astype(q.dtype)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", p16, vcur).astype(jnp.float32)
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, sq, h, d), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (jnp.arange(nchunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    if return_stats:
        return out.astype(q.dtype), m, l       # m,l: (B,H,Sq) f32
    return out.astype(q.dtype)


def sdpa_decode(q, k_cache, v_cache, valid, *, use_pallas: bool = False):
    """Single-token attention over a (ring-buffer) cache.
    q:(B,1,H,D) k,v:(B,S,KV,D) valid:(B,S) bool slot-filled mask.
    jnp oracle of kernels/decode_attention.py."""
    if use_pallas:
        from repro.kernels.ops import flash_decode
        return flash_decode(q, k_cache, v_cache, valid)
    h, kv = q.shape[2], k_cache.shape[2]
    k = _repeat_kv(k_cache, h // kv)
    v = _repeat_kv(v_cache, h // kv)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    s = jnp.where(valid[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, -1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


# --------------------------------------------------------------------------
# GQA attention block
# --------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, cross: bool = False) -> PyTree:
    ks = keygen(key)
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "norm": jnp.ones((d,)),
        "wq": dense_init(next(ks), (d, h * hd)),
        "wk": dense_init(next(ks), (d, kv * hd)),
        "wv": dense_init(next(ks), (d, kv * hd)),
        "wo": dense_init(next(ks), (h * hd, d), scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,))
        p["bk"] = jnp.zeros((kv * hd,))
        p["bv"] = jnp.zeros((kv * hd,))
    if cross:
        p["cross_norm"] = jnp.ones((d,))
        p["cwq"] = dense_init(next(ks), (d, h * hd))
        p["cwk"] = dense_init(next(ks), (d, kv * hd))
        p["cwv"] = dense_init(next(ks), (d, kv * hd))
        p["cwo"] = dense_init(next(ks), (h * hd, d), scale=1.0 / math.sqrt(h * hd))
    return p


def attn_cache_init(cfg: ModelConfig, batch: int, seq_len: int, window: int,
                    dtype=jnp.bfloat16) -> PyTree:
    size = min(window, seq_len) if window > 0 else seq_len
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, size, kv, hd), dtype),
            "v": jnp.zeros((batch, size, kv, hd), dtype)}


def attn_apply(p, x, *, cfg: ModelConfig, mode: str, cache=None, pos=None,
               window: int = 0, causal: bool = True, chunked: bool = True,
               enc_out=None):
    """GQA attention. In decode mode, (cache, pos) hold/advance the KV ring."""
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    b, s, _ = xn.shape
    q = (xn @ p["wq"] + p.get("bq", 0)).reshape(b, s, h, hd)
    k = (xn @ p["wk"] + p.get("bk", 0)).reshape(b, s, kv, hd)
    v = (xn @ p["wv"] + p.get("bv", 0)).reshape(b, s, kv, hd)

    if mode == "decode":
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
        size = cache["k"].shape[1]
        slot = (pos % size)
        # mask-based ring write: elementwise, shards on ANY cache dim (a
        # per-batch dynamic_update_slice lowers to scatter -> SPMD full
        # rematerialization + GB-scale all-gathers; see EXPERIMENTS.md §Perf
        # H2). The full-cache touch is free: the cache is re-emitted through
        # the layer scan anyway.
        oh = (jnp.arange(size)[None, :] == slot[:, None])    # (B, S)
        k_cache = jnp.where(oh[:, :, None, None], k.astype(cache["k"].dtype),
                            cache["k"])
        v_cache = jnp.where(oh[:, :, None, None], v.astype(cache["v"].dtype),
                            cache["v"])
        valid = jnp.arange(size)[None, :] <= jnp.minimum(pos, size - 1)[:, None]
        o = sdpa_decode(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                        valid)
        cache = {"k": k_cache, "v": v_cache}
    else:
        positions = jnp.arange(s)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if chunked and s > 2048:
            o = sdpa_chunked(q, k, v, causal=causal, window=window)
        else:
            o = sdpa_full(q, k, v, causal=causal, window=window)

    y = o.reshape(b, s, h * hd) @ p["wo"]

    if enc_out is not None:                    # whisper decoder cross-attn
        xn2 = rms_norm(x + y, p["cross_norm"], cfg.norm_eps)
        cq = (xn2 @ p["cwq"]).reshape(b, s, h, hd)
        ck = (enc_out @ p["cwk"]).reshape(b, enc_out.shape[1], kv, hd)
        cv = (enc_out @ p["cwv"]).reshape(b, enc_out.shape[1], kv, hd)
        co = sdpa_full(cq, ck, cv, causal=False, window=0)
        y = y + co.reshape(b, s, h * hd) @ p["cwo"]
    return y, cache


# --------------------------------------------------------------------------
# MLA attention (deepseek-v2)
# --------------------------------------------------------------------------
def mla_init(key, cfg: ModelConfig) -> PyTree:
    ks = keygen(key)
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    p = {"norm": jnp.ones((d,))}
    if qr > 0:
        p["w_dq"] = dense_init(next(ks), (d, qr))
        p["q_norm"] = jnp.ones((qr,))
        p["w_uq"] = dense_init(next(ks), (qr, h * (dn + dr)))
    else:
        p["w_q"] = dense_init(next(ks), (d, h * (dn + dr)))
    p["w_dkv"] = dense_init(next(ks), (d, r))
    p["kv_norm"] = jnp.ones((r,))
    p["w_uk"] = dense_init(next(ks), (r, h * dn))
    p["w_uv"] = dense_init(next(ks), (r, h * dv))
    p["w_kr"] = dense_init(next(ks), (d, dr))
    p["wo"] = dense_init(next(ks), (h * dv, d), scale=1.0 / math.sqrt(h * dv))
    return p


def mla_cache_init(cfg: ModelConfig, batch: int, seq_len: int,
                   dtype=jnp.bfloat16) -> PyTree:
    return {"c_kv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, seq_len, cfg.qk_rope_head_dim), dtype)}


def _mla_qkv(p, xn, cfg):
    b, s, _ = xn.shape
    h = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if "w_dq" in p:
        q = rms_norm(xn @ p["w_dq"], p["q_norm"], cfg.norm_eps) @ p["w_uq"]
    else:
        q = xn @ p["w_q"]
    q = q.reshape(b, s, h, dn + dr)
    c_kv = rms_norm(xn @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)  # (b,s,r)
    k_rope = xn @ p["w_kr"]                                        # (b,s,dr)
    return q, c_kv, k_rope


def mla_apply(p, x, *, cfg: ModelConfig, mode: str, cache=None, pos=None,
              window: int = 0, absorbed: bool = False, chunked: bool = True,
              **_):
    """MLA. ``absorbed=False`` is the naive baseline that reconstructs per-head
    K/V from the latent cache (the §Perf hillclimb switches decode to the
    absorbed form, which attends in the kv_lora latent space)."""
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    b, s, _ = xn.shape
    q, c_kv, k_rope = _mla_qkv(p, xn, cfg)

    if mode == "decode":
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)
        k_rope = apply_rope(k_rope[:, :, None, :], pos[:, None],
                            cfg.rope_theta)[:, :, 0]
        size = cache["c_kv"].shape[1]
        slot = pos % size
        # mask-based ring write (see attn_apply) — scatter-free, shardable
        oh = (jnp.arange(size)[None, :] == slot[:, None])[..., None]
        ckv_c = jnp.where(oh, c_kv.astype(cache["c_kv"].dtype),
                          cache["c_kv"])
        kr_c = jnp.where(oh, k_rope.astype(cache["k_rope"].dtype),
                         cache["k_rope"])
        valid = jnp.arange(size)[None, :] <= jnp.minimum(pos, size - 1)[:, None]
        scale = 1.0 / math.sqrt(dn + dr)
        if absorbed:
            # fold W_uk into q: attend directly in the r-dim latent space
            w_uk = p["w_uk"].reshape(-1, h, dn)                 # (r,h,dn)
            q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)  # (b,1,h,r)
            s_lat = jnp.einsum("bqhr,bkr->bhqk", q_lat,
                               ckv_c.astype(q.dtype))
            s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope,
                                kr_c.astype(q.dtype))
            att = (s_lat + s_rope).astype(jnp.float32) * scale
            att = jnp.where(valid[:, None, None, :], att, NEG)
            pr = jax.nn.softmax(att, -1).astype(q.dtype)
            o_lat = jnp.einsum("bhqk,bkr->bqhr", pr, ckv_c.astype(q.dtype))
            w_uv = p["w_uv"].reshape(-1, h, dv)                 # (r,h,dv)
            o = jnp.einsum("bqhr,rhv->bqhv", o_lat, w_uv)
        else:
            # naive: reconstruct per-head K/V for every cached position
            k_nope = (ckv_c.astype(q.dtype) @ p["w_uk"]).reshape(b, size, h, dn)
            vfull = (ckv_c.astype(q.dtype) @ p["w_uv"]).reshape(b, size, h, dv)
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kr_c[:, :, None, :].astype(q.dtype),
                                          (b, size, h, dr))], -1)
            q_full = jnp.concatenate([q_nope, q_rope], -1)
            att = jnp.einsum("bqhd,bkhd->bhqk", q_full, k_full
                             ).astype(jnp.float32) * scale
            att = jnp.where(valid[:, None, None, :], att, NEG)
            pr = jax.nn.softmax(att, -1).astype(q.dtype)
            o = jnp.einsum("bhqk,bkhd->bqhd", pr, vfull)
        cache = {"c_kv": ckv_c, "k_rope": kr_c}
    else:
        positions = jnp.arange(s)
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
        k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, dn)
        vfull = (c_kv @ p["w_uv"]).reshape(b, s, h, dv)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        # pad v to qk head dim so the shared SDPA cores apply, then slice back
        if chunked and s > 2048:
            vpad = jnp.pad(vfull, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
            o = sdpa_chunked(q_full, k_full, vpad, causal=True,
                             window=window)[..., :dv]
        else:
            vpad = jnp.pad(vfull, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
            o = sdpa_full(q_full, k_full, vpad, causal=True,
                          window=window)[..., :dv]
    y = o.reshape(b, s, h * dv) @ p["wo"]
    return y, cache


# --------------------------------------------------------------------------
# FFN (dense) and MoE
# --------------------------------------------------------------------------
def ffn_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> PyTree:
    ks = keygen(key)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {"norm": jnp.ones((d,)),
            "w_gate": dense_init(next(ks), (d, f)),
            "w_up": dense_init(next(ks), (d, f)),
            "w_down": dense_init(next(ks), (f, d), scale=1.0 / math.sqrt(f))}


def ffn_apply(p, x, *, cfg: ModelConfig):
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    return (act_fn(cfg.act)(xn @ p["w_gate"]) * (xn @ p["w_up"])) @ p["w_down"]


def moe_init(key, cfg: ModelConfig) -> PyTree:
    ks = keygen(key)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {"norm": jnp.ones((d,)),
         "router": dense_init(next(ks), (d, e), scale=0.02),
         "we_gate": dense_init(next(ks), (e, d, f)),
         "we_up": dense_init(next(ks), (e, d, f)),
         "we_down": dense_init(next(ks), (e, f, d), scale=1.0 / math.sqrt(f))}
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["ws_gate"] = dense_init(next(ks), (d, fs))
        p["ws_up"] = dense_init(next(ks), (d, fs))
        p["ws_down"] = dense_init(next(ks), (fs, d), scale=1.0 / math.sqrt(fs))
    return p


def moe_apply(p, x, *, cfg: ModelConfig, capacity_factor: float = 1.25,
              group_size: int = 512):
    """GShard-style einsum dispatch MoE (top-k, capacity-dropped).

    Tokens are grouped; each group dispatches to per-expert capacity slots via
    one-hot einsums — fully SPMD-shardable (experts over the model axis give
    expert parallelism; groups follow the batch over the data axis). The
    dispatch einsums' FLOPs/bytes are real and show up in the roofline (that
    overhead is a documented hillclimb axis; see EXPERIMENTS.md §Perf)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    flat = xn.reshape(-1, d)
    n = flat.shape[0]
    g = max(n // group_size, 1)
    gs = n // g
    flat = flat[: g * gs].reshape(g, gs, d)

    logits = flat @ p["router"]                                   # (g,gs,e)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    topv, topi = jax.lax.top_k(probs, k)                          # (g,gs,k)
    topv = (topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
            ).astype(x.dtype)

    cap = max(int(gs * k / e * capacity_factor), 1)
    # position of each (token, choice) within its expert's capacity
    oh = jax.nn.one_hot(topi, e, dtype=jnp.int32)                 # (g,gs,k,e)
    pos_in_e = jnp.cumsum(oh.reshape(g, gs * k, e), 1).reshape(g, gs, k, e) - 1
    pos_in_e = (pos_in_e * oh).sum(-1)                            # (g,gs,k)
    keep = pos_in_e < cap
    slot = jnp.where(keep, pos_in_e, cap)                         # cap == dropped
    onehot_e = jax.nn.one_hot(topi, e, dtype=x.dtype)             # (g,gs,k,e)
    onehot_c = jax.nn.one_hot(slot, cap + 1, dtype=x.dtype)[..., :cap]
    # dispatch/combine tensors (g, gs, e, cap)
    disp = jnp.einsum("gske,gskc->gsec", onehot_e, onehot_c)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot_e, onehot_c, topv)

    xe = jnp.einsum("gsec,gsd->egcd", disp, flat)                 # (e,g,cap,d)
    he = act_fn(cfg.act)(jnp.einsum("egcd,edf->egcf", xe, p["we_gate"])) \
        * jnp.einsum("egcd,edf->egcf", xe, p["we_up"])
    ye = jnp.einsum("egcf,efd->egcd", he, p["we_down"])            # (e,g,cap,d)
    y = jnp.einsum("gsec,egcd->gsd", combine, ye).reshape(-1, d)
    if g * gs < n:
        y = jnp.pad(y, ((0, n - g * gs), (0, 0)))
    y = y.reshape(b, s, d)

    if cfg.num_shared_experts:
        y = y + (act_fn(cfg.act)(xn @ p["ws_gate"]) * (xn @ p["ws_up"])
                 ) @ p["ws_down"]
    # router z-loss / aux load-balance loss (returned via aux, summed outside)
    me = probs.mean((0, 1))
    ce = jax.nn.one_hot(topi[..., 0], e).mean((0, 1))
    aux = cfg.router_aux_loss * e * jnp.sum(me * ce)
    return y, aux


# --------------------------------------------------------------------------
# Mamba (jamba's SSM mixer)
# --------------------------------------------------------------------------
def mamba_init(key, cfg: ModelConfig) -> PyTree:
    ks = keygen(key)
    d, di, st, cw = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_conv_width
    dt_rank = max(d // 16, 1)
    return {
        "norm": jnp.ones((d,)),
        "w_in": dense_init(next(ks), (d, 2 * di)),
        "conv_w": dense_init(next(ks), (cw, di), scale=1.0 / math.sqrt(cw)),
        "conv_b": jnp.zeros((di,)),
        "w_x": dense_init(next(ks), (di, dt_rank + 2 * st)),
        "w_dt": dense_init(next(ks), (dt_rank, di)),
        "dt_bias": jnp.full((di,), -4.6),            # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, st + 1, dtype=jnp.float32), (di, st)) * 1.0),
        "D": jnp.ones((di,)),
        "w_out": dense_init(next(ks), (di, d), scale=1.0 / math.sqrt(di)),
    }


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> PyTree:
    di, st, cw = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_conv_width
    return {"conv": jnp.zeros((batch, cw - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, st), dtype)}


def _selective_scan(u, dt, A, B, C, D, chunk: int = 256):
    """h_t = exp(dt A) h_{t-1} + dt B_t u_t ; y_t = C_t.h_t + D u_t.
    Chunked: sequential lax.scan over chunks, associative scan within.
    u:(b,s,di) dt:(b,s,di) A:(di,st) B,C:(b,s,st)."""
    b, s, di = u.shape
    st = A.shape[1]
    nch = max(s // chunk, 1)
    chunk = s // nch
    dA = jnp.exp(dt[..., None] * A)                    # (b,s,di,st)
    dBu = dt[..., None] * B[:, :, None, :] * u[..., None]

    dA_c = dA.reshape(b, nch, chunk, di, st)
    dBu_c = dBu.reshape(b, nch, chunk, di, st)
    C_c = C.reshape(b, nch, chunk, st)

    def outer(h, xs):
        da, dbu, c = xs                               # (b,chunk,di,st)...
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        aa, hh = jax.lax.associative_scan(combine, (da, dbu), axis=1)
        hh = hh + aa * h[:, None]                     # inject carry
        y = jnp.einsum("bcds,bcs->bcd", hh, c)
        return hh[:, -1], y

    h0 = jnp.zeros((b, di, st), dA.dtype)
    _, ys = jax.lax.scan(outer, h0,
                         (dA_c.transpose(1, 0, 2, 3, 4),
                          dBu_c.transpose(1, 0, 2, 3, 4),
                          C_c.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    return y + u * D


def mamba_apply(p, x, *, cfg: ModelConfig, mode: str, cache=None, **_):
    di, st, cw = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_conv_width
    dt_rank = max(cfg.d_model // 16, 1)
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    b, s, _ = xn.shape
    xz = xn @ p["w_in"]
    u, z = xz[..., :di], xz[..., di:]

    if mode == "decode":
        conv_state = jnp.concatenate([cache["conv"], u.astype(cache["conv"].dtype)], 1)
        uc = jnp.einsum("bwd,wd->bd", conv_state.astype(u.dtype),
                        p["conv_w"]) + p["conv_b"]
        uc = jax.nn.silu(uc)[:, None]                  # (b,1,di)
        dbc = uc @ p["w_x"]
        dt = jax.nn.softplus(dbc[..., :dt_rank] @ p["w_dt"] + p["dt_bias"])
        B = dbc[..., dt_rank:dt_rank + st]
        C = dbc[..., dt_rank + st:]
        A = -jnp.exp(p["A_log"])
        dA = jnp.exp(dt[:, 0, :, None] * A)            # (b,di,st)
        h = cache["ssm"].astype(dA.dtype) * dA \
            + dt[:, 0, :, None] * B[:, 0, None, :] * uc[:, 0, :, None]
        y = jnp.einsum("bds,bs->bd", h, C[:, 0])[:, None] + uc * p["D"]
        cache = {"conv": conv_state[:, 1:].astype(cache["conv"].dtype),
                 "ssm": h.astype(cache["ssm"].dtype)}
    else:
        upad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
        uc = sum(upad[:, i:i + s] * p["conv_w"][i] for i in range(cw)) \
            + p["conv_b"]
        uc = jax.nn.silu(uc)
        dbc = uc @ p["w_x"]
        dt = jax.nn.softplus(dbc[..., :dt_rank] @ p["w_dt"] + p["dt_bias"])
        B = dbc[..., dt_rank:dt_rank + st]
        C = dbc[..., dt_rank + st:]
        A = -jnp.exp(p["A_log"])
        y = _selective_scan(uc, dt, A, B, C, p["D"])
    y = y * jax.nn.silu(z)
    return (y @ p["w_out"]), cache


# --------------------------------------------------------------------------
# RWKV6 (Finch) time-mix block — data-dependent decay linear attention
# --------------------------------------------------------------------------
def rwkv_init(key, cfg: ModelConfig) -> PyTree:
    ks = keygen(key)
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    lora = max(d // 16, 32)
    return {
        "norm": jnp.ones((d,)),
        "mu_r": jnp.full((d,), 0.5), "mu_k": jnp.full((d,), 0.5),
        "mu_v": jnp.full((d,), 0.5), "mu_w": jnp.full((d,), 0.5),
        "mu_g": jnp.full((d,), 0.5),
        "wr": dense_init(next(ks), (d, h * hd)),
        "wk": dense_init(next(ks), (d, h * hd)),
        "wv": dense_init(next(ks), (d, h * hd)),
        "wg": dense_init(next(ks), (d, h * hd)),
        # data-dependent decay (the Finch contribution): w = f(x) via LoRA
        "w_decay1": dense_init(next(ks), (d, lora)),
        "w_decay2": dense_init(next(ks), (lora, h * hd)),
        "decay_bias": jnp.full((h * hd,), -6.0),
        "bonus": jnp.zeros((h, hd)),
        "ln_x": jnp.ones((h * hd,)),
        "wo": dense_init(next(ks), (h * hd, d), scale=1.0 / math.sqrt(h * hd)),
    }


def rwkv_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> PyTree:
    h, hd = cfg.num_heads, cfg.head_dim
    return {"state": jnp.zeros((batch, h, hd, hd), dtype),
            "x_prev": jnp.zeros((batch, cfg.d_model), dtype)}


def _wkv_chunked(r, k, v, w, u, chunk: int = 64):
    """Chunked linear attention with per-step diagonal decay (f32 internals).
    r,k,v,w: (b,s,h,hd); w in (0,1) decay; u bonus (h,hd).
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ; o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

    chunk=64 keeps exp(-cum) within f32 range given log(w) >= -0.61 (the decay
    parameterization in rwkv_apply bounds it); this is the jnp oracle a wkv
    Pallas kernel would mirror."""
    b, s, h, hd = r.shape
    nch = max(s // chunk, 1)
    chunk = s // nch

    rc = r.reshape(b, nch, chunk, h, hd).transpose(1, 0, 3, 2, 4)  # (n,b,h,c,hd)
    kc = k.reshape(b, nch, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nch, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    wc = w.reshape(b, nch, chunk, h, hd).transpose(1, 0, 3, 2, 4)

    def body(S, xs):
        rr, kk, vv, ww = (t.astype(jnp.float32) for t in xs)  # (b,h,c,hd)
        logw = jnp.log(jnp.maximum(ww, 1e-6))
        cum = jnp.cumsum(logw, 2)               # sum of log-decays up to & incl t
        # inter-chunk: r_i sees S0 through decay prod_{l<i} w_l = exp(cum_{i-1})
        r_dec = rr * jnp.exp(cum - logw)
        o = jnp.einsum("bhcd,bhde->bhce", r_dec, S)
        # intra-chunk pair (i, j<i): coeff exp(cum_{i-1} - cum_j) per dim d
        k_dec = kk * jnp.exp(-cum)
        att = jnp.einsum("bhcd,bhed->bhce", r_dec, k_dec)      # (b,h,i,j)
        tri = jnp.tril(jnp.ones((chunk, chunk), att.dtype), -1)
        att = att * tri
        # bonus term (diagonal): r_t . (u * k_t) v_t
        diag = jnp.einsum("bhcd,bhcd->bhc", rr, kk * u[None, :, None, :])
        o = o + jnp.einsum("bhce,bhed->bhcd", att, vv) + diag[..., None] * vv
        # state update: S <- diag(prod w) S + sum_j (prod_{l>j} w_l) k_j v_j^T
        wall = jnp.exp(cum[:, :, -1])
        k_rem = kk * jnp.exp(cum[:, :, -1:] - cum)
        S = S * wall[..., None] + jnp.einsum("bhcd,bhce->bhde", k_rem, vv)
        return S, o

    S0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    _, os = jax.lax.scan(body, S0, (rc, kc, vc, wc))
    return os.transpose(1, 0, 3, 2, 4).reshape(b, s, h, hd).astype(r.dtype)


def rwkv_apply(p, x, *, cfg: ModelConfig, mode: str, cache=None, **_):
    h, hd = cfg.num_heads, cfg.head_dim
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    b, s, d = xn.shape

    if mode == "decode":
        x_prev = cache["x_prev"][:, None].astype(xn.dtype)
    else:
        x_prev = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1]

    def mix(mu):
        return xn + (x_prev - xn) * mu

    r = (mix(p["mu_r"]) @ p["wr"]).reshape(b, s, h, hd)
    k = (mix(p["mu_k"]) @ p["wk"]).reshape(b, s, h, hd)
    v = (mix(p["mu_v"]) @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["wg"])
    dec = jax.nn.sigmoid(
        (jax.nn.tanh(mix(p["mu_w"]) @ p["w_decay1"]) @ p["w_decay2"])
        + p["decay_bias"]).reshape(b, s, h, hd)
    # data-dependent decay (Finch): w in (exp(-0.6065), 1); the bound keeps
    # the chunked form's exp(-cumsum(log w)) inside f32 range (see _wkv_chunked)
    w = jnp.exp(-0.6065 * dec)

    if mode == "decode":
        S = cache["state"].astype(jnp.float32)               # (b,h,hd,hd)
        r1, k1, v1, w1 = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
        kv = jnp.einsum("bhd,bhe->bhde", k1, v1)
        # o_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
        o = jnp.einsum("bhd,bhde->bhe", r1,
                       S + p["bonus"][None, :, :, None] * kv)
        S = S * w1[..., None] + kv
        cache = {"state": S.astype(cache["state"].dtype),
                 "x_prev": xn[:, -1].astype(cache["x_prev"].dtype)}
        o = o[:, None].astype(r.dtype)
    else:
        o = _wkv_chunked(r, k, v, w, p["bonus"])

    o = o.reshape(b, s, h * hd)
    o = rms_norm(o, p["ln_x"], cfg.norm_eps) * g
    return (o @ p["wo"]), cache


# --------------------------------------------------------------------------
# RWKV6 channel-mix (its FFN variant)
# --------------------------------------------------------------------------
def rwkv_ffn_init(key, cfg: ModelConfig) -> PyTree:
    ks = keygen(key)
    d, f = cfg.d_model, cfg.d_ff
    return {"norm": jnp.ones((d,)),
            "mu_k": jnp.full((d,), 0.5), "mu_r": jnp.full((d,), 0.5),
            "wk": dense_init(next(ks), (d, f)),
            "wv": dense_init(next(ks), (f, d), scale=1.0 / math.sqrt(f)),
            "wr": dense_init(next(ks), (d, d))}


def rwkv_ffn_apply(p, x, *, cfg: ModelConfig, x_prev=None):
    """Returns (out, xn_last) — xn_last is the decode-mode token-shift state."""
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    if x_prev is None:
        xp = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xp = x_prev[:, None].astype(xn.dtype)
    k = (xn + (xp - xn) * p["mu_k"]) @ p["wk"]
    r = jax.nn.sigmoid((xn + (xp - xn) * p["mu_r"]) @ p["wr"])
    return r * (jnp.square(jax.nn.relu(k)) @ p["wv"]), xn[:, -1]
