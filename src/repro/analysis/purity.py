"""Tracer-safety rules (PUR001–PUR004).

Inside a traced context — a function decorated with ``jax.jit`` (directly or
via ``functools.partial(jax.jit, static_argnames=...)``), a body passed to
``jax.lax.map``/``scan``/``fori_loop``/``while_loop``/``cond``/``switch``,
``shard_map``, ``vmap``/``pmap``, or a Pallas kernel — Python-level control
flow and host casts silently see tracers, not values.

PUR001  Python ``if``/``while`` on a traced value (use ``jnp.where`` /
        ``lax.cond`` / ``pl.when``)
PUR002  host cast of a traced value: ``float()``/``int()``/``bool()``/
        ``np.*`` / ``.item()`` / ``.tolist()``
PUR003  Python randomness or wall-clock time inside traced code
        (``random.*``, ``np.random.*``, ``time.*``) — traces once, then
        is frozen into the compiled program
PUR004  ``assert`` on a traced value

Staticness is tracked per function: parameters are traced except those
named in ``static_argnames`` or bound by ``functools.partial``; shape
metadata (``.shape``/``.ndim``/``.dtype``/``.size``, ``len()``) is static;
taint propagates through assignments.  ``pl.program_id``/``num_programs``
produce traced values.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Set

from repro.analysis.core import Finding, Module, dotted_name

STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "sharding"}
STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "range", "min",
                "max", "abs", "sum", "tuple", "list", "sorted", "enumerate",
                "zip", "math.sqrt", "math.ceil", "math.floor", "math.log",
                "math.log2", "cdiv", "pl.cdiv"}
HOST_CASTS = {"float", "int", "bool", "complex"}
HOST_CAST_METHODS = {"item", "tolist", "numpy"}
IMPURE_PREFIXES = ("time.", "random.", "np.random.", "numpy.random.",
                   "datetime.")
TRACED_PRODUCERS = {"pl.program_id", "pl.num_programs", "pltpu.prng_seed"}
LAX_HOF = {"jax.lax.map", "lax.map", "jax.lax.scan", "lax.scan",
           "jax.lax.fori_loop", "lax.fori_loop", "jax.lax.while_loop",
           "lax.while_loop", "jax.lax.cond", "lax.cond", "jax.lax.switch",
           "lax.switch", "jax.lax.associative_scan", "lax.associative_scan"}
VMAPPERS = {"jax.vmap", "vmap", "jax.pmap", "pmap", "shard_map",
            "jax.experimental.shard_map.shard_map"}


def _jit_static_argnames(deco: ast.expr) -> Optional[Set[str]]:
    """Static argnames if this decorator makes the function jitted."""
    d = dotted_name(deco)
    if d in ("jax.jit", "jit"):
        return set()
    if isinstance(deco, ast.Call):
        fn = dotted_name(deco.func)
        if fn in ("jax.jit", "jit"):
            return _static_names_from_kw(deco.keywords)
        if fn in ("functools.partial", "partial") and deco.args:
            inner = dotted_name(deco.args[0])
            if inner in ("jax.jit", "jit"):
                return _static_names_from_kw(deco.keywords)
    return None


def _static_names_from_kw(keywords: Sequence[ast.keyword]) -> Set[str]:
    out: Set[str] = set()
    for kw in keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    out.add(sub.value)
    return out


def _partial_bindings(mod: Module, fn_name: str) -> Optional[Set[str]]:
    """Names statically bound when fn is only invoked via functools.partial.

    Returns None if the function is never partial-bound.  Positional
    partial args bind the first k parameters; keyword args bind by name.
    """
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if dotted_name(node.func) not in ("functools.partial", "partial"):
            continue
        if not node.args or dotted_name(node.args[0]) != fn_name:
            continue
        bound_kw = {kw.arg for kw in node.keywords if kw.arg}
        return {"__npos__%d" % (len(node.args) - 1)} | bound_kw
    return None


class _FnCheck(ast.NodeVisitor):
    def __init__(self, mod: Module, fn: ast.FunctionDef,
                 static_params: Set[str], is_kernel: bool):
        self.mod = mod
        self.fn = fn
        self.is_kernel = is_kernel
        self.findings: List[Finding] = []
        args = fn.args
        all_params = [a.arg for a in args.posonlyargs + args.args
                      + args.kwonlyargs]
        self.traced: Set[str] = {p for p in all_params
                                 if p not in static_params
                                 and p not in ("self", "cls")}

    # -- staticness -------------------------------------------------------

    def is_static(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            return node.id not in self.traced
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return True
            return self.is_static(node.value)
        if isinstance(node, ast.Subscript):
            # shape[0] is static; x[0] of a traced x is traced
            return self.is_static(node.value)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return all(self.is_static(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return all(self.is_static(v) for v in
                       list(node.keys or []) + list(node.values or [])
                       if v is not None)
        if isinstance(node, ast.BinOp):
            return self.is_static(node.left) and self.is_static(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_static(node.operand)
        if isinstance(node, ast.BoolOp):
            return all(self.is_static(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` is a static structural check
            # even on a traced name (tracers are never None)
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) \
                    and all(isinstance(c, ast.Constant) and c.value is None
                            for c in node.comparators):
                return True
            return self.is_static(node.left) and all(
                self.is_static(c) for c in node.comparators)
        if isinstance(node, ast.IfExp):
            return all(self.is_static(n) for n in
                       (node.test, node.body, node.orelse))
        if isinstance(node, ast.Call):
            fn = dotted_name(node.func) or ""
            if fn in TRACED_PRODUCERS or fn.endswith(".program_id") \
                    or fn.endswith(".num_programs"):
                return False
            # a method call on a traced receiver (x.sum(), q.astype(...))
            # produces a traced value
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr not in STATIC_ATTRS \
                    and not self.is_static(node.func.value):
                return False
            if fn == "len" or fn in STATIC_CALLS:
                return all(self.is_static(a) for a in node.args)
            return all(self.is_static(a) for a in node.args) and all(
                self.is_static(kw.value) for kw in node.keywords)
        if isinstance(node, ast.JoinedStr):
            return True
        return True  # unknown constructs: assume static (precision first)

    # -- taint propagation + checks ---------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        tainted = not self.is_static(node.value)
        for t in node.targets:
            for sub in ast.walk(t):
                if isinstance(sub, ast.Name):
                    if tainted:
                        self.traced.add(sub.id)
                    else:
                        self.traced.discard(sub.id)

    def visit_If(self, node: ast.If) -> None:
        if not self.is_static(node.test):
            self._flag("PUR001", node.test.lineno,
                       "Python `if` on a traced value inside traced code",
                       "use jnp.where / lax.cond"
                       + (" / pl.when" if self.is_kernel else ""))
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        if not self.is_static(node.test):
            self._flag("PUR001", node.test.lineno,
                       "Python `while` on a traced value inside traced code",
                       "use lax.while_loop")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        if not self.is_static(node.test):
            self._flag("PUR004", node.test.lineno,
                       "`assert` on a traced value inside traced code",
                       "assert on static shapes/dtypes only, or use "
                       "checkify/debug.check")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        fn = dotted_name(node.func)
        if fn:
            if fn in HOST_CASTS and node.args \
                    and not self.is_static(node.args[0]):
                self._flag("PUR002", node.lineno,
                           f"host-side `{fn}()` cast of a traced value",
                           "keep it on-device (jnp) or hoist out of the "
                           "traced region")
            elif (fn.startswith(("np.", "numpy."))
                  and not fn.startswith(IMPURE_PREFIXES)
                  and any(not self.is_static(a) for a in node.args)):
                self._flag("PUR002", node.lineno,
                           f"`{fn}` applied to a traced value forces a "
                           "host transfer",
                           "use the jnp equivalent inside traced code")
            if fn.startswith(IMPURE_PREFIXES):
                self._flag("PUR003", node.lineno,
                           f"impure host call `{fn}` inside traced code is "
                           "frozen at trace time",
                           "pass PRNG keys / timestamps in as arguments")
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in HOST_CAST_METHODS \
                and not self.is_static(node.func.value):
            self._flag("PUR002", node.lineno,
                       f"`.{node.func.attr}()` on a traced value",
                       "hoist host materialization out of the traced region")
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if node is not self.fn:
            return  # nested defs get their own context if traced
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def _flag(self, rule: str, line: int, msg: str, hint: str) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.mod.path, line=line,
            message=f"{msg} (in `{self.fn.name}`)", hint=hint))


def _traced_functions(mod: Module):
    """Yield (FunctionDef, static_param_names, is_kernel) for traced defs."""
    by_name = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, node)

    seen: Set[str] = set()

    # 1) jit-decorated functions
    for node in by_name.values():
        for deco in node.decorator_list:
            statics = _jit_static_argnames(deco)
            if statics is not None:
                seen.add(node.name)
                yield node, _expand_static(node, statics), False
                break

    # 2) bodies handed to lax HOFs / vmap / shard_map, and Pallas kernels
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = dotted_name(node.func) or ""
        is_pallas = fn.endswith("pallas_call")
        is_jit_call = fn in ("jax.jit", "jit")
        if not (fn in LAX_HOF or fn in VMAPPERS or is_pallas or is_jit_call
                or fn.split(".")[-1] in ("shard_map",)):
            continue
        for arg in node.args[:1] if (is_pallas or is_jit_call) else node.args:
            target, bound = _resolve_fn_arg(arg)
            if target is None or target not in by_name:
                continue
            if target in seen:
                continue
            seen.add(target)
            fndef = by_name[target]
            statics = set(bound)
            if is_jit_call:
                statics |= _static_names_from_kw(node.keywords)
            pb = _partial_bindings(mod, target)
            if pb:
                statics |= _positional_expand(fndef, pb)
            if is_pallas:
                # keyword-only params of a kernel are always static config
                statics |= {a.arg for a in fndef.args.kwonlyargs}
            yield fndef, _expand_static(fndef, statics), is_pallas


def _resolve_fn_arg(arg: ast.expr):
    """(function_name, statically_bound_param_markers) for a callable arg."""
    if isinstance(arg, ast.Name):
        return arg.id, set()
    if isinstance(arg, ast.Call) \
            and dotted_name(arg.func) in ("functools.partial", "partial") \
            and arg.args and isinstance(arg.args[0], ast.Name):
        bound = {"__npos__%d" % (len(arg.args) - 1)}
        bound |= {kw.arg for kw in arg.keywords if kw.arg}
        return arg.args[0].id, bound
    return None, set()


def _positional_expand(fndef: ast.FunctionDef, markers: Set[str]) -> Set[str]:
    out = {m for m in markers if not m.startswith("__npos__")}
    npos = max((int(m[len("__npos__"):]) for m in markers
                if m.startswith("__npos__")), default=0)
    params = [a.arg for a in fndef.args.posonlyargs + fndef.args.args]
    out.update(params[:npos])
    return out


def _expand_static(fndef: ast.FunctionDef, statics: Set[str]) -> Set[str]:
    statics = _positional_expand(fndef, statics)
    # static_argnums indices arrive as strings of digits from kw parsing;
    # map any pure-digit entries onto parameter names
    params = [a.arg for a in fndef.args.posonlyargs + fndef.args.args]
    for s in list(statics):
        if s.isdigit() and int(s) < len(params):
            statics.add(params[int(s)])
    return statics


def check(mod: Module) -> List[Finding]:
    findings: List[Finding] = []
    for fndef, statics, is_kernel in _traced_functions(mod):
        chk = _FnCheck(mod, fndef, statics, is_kernel)
        for stmt in fndef.body:
            chk.visit(stmt)
        findings.extend(chk.findings)
    return findings
