"""Embedded known-bad / known-good fixtures — ``flcheck --self-test``.

Every rule family ships a minimal fixture that must fire and a clean twin
that must stay silent, so the checker's own regressions are caught by the
same CI job that runs it (and ``benchmarks/run.py --only analysis`` times
this suite alongside the full ``src/`` scan).
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, List, NamedTuple, Optional

from repro.analysis.core import run_analysis


class Fixture(NamedTuple):
    name: str
    rule: Optional[str]       # rule that must fire; None => must be clean
    files: Dict[str, str]     # relpath -> source


FIXTURES: List[Fixture] = [
    Fixture("rng001_reuse_after_split", "RNG001", {"mod.py": """
import jax

def f(key):
    keys = jax.random.split(key, 4)
    k2 = jax.random.fold_in(key, 1)
    return keys, k2
"""}),
    Fixture("rng_clean_split_tree", None, {"mod.py": """
import jax

def f(key):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (4,))
    y = jax.random.normal(k2, (4,))
    return x + y
"""}),
    Fixture("rng002_double_draw", "RNG002", {"mod.py": """
import jax

def f(key):
    x = jax.random.normal(key, (4,))
    y = jax.random.uniform(key, (4,))
    return x + y
"""}),
    # the PR 1 server-key bug: cohort consumes the whole key array AND the
    # server aliases keys[-1]
    Fixture("rng003_keys_minus_one_aliasing", "RNG003", {"mod.py": """
import jax

def run_round(key, clients, run_cohort, server_round):
    keys = jax.random.split(key, len(clients))
    outs = run_cohort(clients, keys)
    k_server = keys[-1]
    return outs, server_round(k_server)
"""}),
    Fixture("rng003_disjoint_slices_ok", None, {"mod.py": """
import jax

def run_round(key, clients, run_cohort, server_round):
    keys = jax.random.split(key, len(clients) + 1)
    outs = run_cohort(clients, keys[:-1])
    k_server = keys[-1]
    return outs, server_round(k_server)
"""}),
    Fixture("rng004_loop_invariant_key", "RNG004", {"mod.py": """
import jax

def f(key, clients):
    outs = []
    for c in clients:
        k = jax.random.fold_in(key, 0)
        outs.append(k)
    return outs
"""}),
    Fixture("rng004_folds_loop_var_ok", None, {"mod.py": """
import jax

def f(key, clients):
    outs = []
    for i, c in enumerate(clients):
        k = jax.random.fold_in(key, i)
        outs.append(k)
    return outs
"""}),
    Fixture("pur001_if_on_tracer", "PUR001", {"mod.py": """
import jax

@jax.jit
def f(x):
    if x > 0:
        return x
    return -x
"""}),
    Fixture("pur001_static_shape_if_ok", None, {"mod.py": """
import jax

@jax.jit
def f(x):
    n, = x.shape
    if n > 4:
        return x[:4]
    return x
"""}),
    Fixture("pur002_host_cast", "PUR002", {"mod.py": """
import jax

@jax.jit
def f(x):
    return float(x)
"""}),
    Fixture("pur003_time_in_jit", "PUR003", {"mod.py": """
import jax
import time

@jax.jit
def f(x):
    t = time.time()
    return x + t
"""}),
    Fixture("pur004_assert_on_tracer", "PUR004", {"mod.py": """
import jax

@jax.jit
def f(x):
    assert x.sum() > 0
    return x
"""}),
    Fixture("pal001_lane_misaligned", "PAL001", {"mod.py": """
import jax
from jax.experimental import pallas as pl

def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def copy_op(x):
    return pl.pallas_call(
        _k,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 100), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 100), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
"""}),
    Fixture("pal002_sublane_misaligned", "PAL002", {"mod.py": """
import jax
from jax.experimental import pallas as pl

def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def copy_op(x):
    return pl.pallas_call(
        _k,
        grid=(4,),
        in_specs=[pl.BlockSpec((4, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
"""}),
    Fixture("pal_aligned_blocks_ok", None, {"mod.py": """
import jax
from jax.experimental import pallas as pl

def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def copy_op(x, block_n=256):
    return pl.pallas_call(
        _k,
        grid=(4,),
        in_specs=[pl.BlockSpec((block_n, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
"""}),
    Fixture("pal003_vmem_blowout", "PAL003", {"mod.py": """
import jax
from jax.experimental import pallas as pl

def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def copy_op(x):
    return pl.pallas_call(
        _k,
        grid=(4,),
        in_specs=[pl.BlockSpec((4096, 1024), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4096, 1024), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
"""}),
    Fixture("pal004_missing_ref_oracle", "PAL004", {"kernels/foo.py": """
import jax
from jax.experimental import pallas as pl

def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def foo_kernel(x):
    return pl.pallas_call(
        _k,
        grid=(1,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
"""}),
    Fixture("pal004_ref_oracle_present_ok", None, {
        "kernels/foo.py": """
import jax
from jax.experimental import pallas as pl

def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def foo_kernel(x):
    return pl.pallas_call(
        _k,
        grid=(1,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
""",
        "kernels/ref.py": """
import jax.numpy as jnp

def foo_ref(x):
    return x
"""}),
    Fixture("led001_uncharged_encode", "LED001", {"mod.py": """
import struct

class Ping:
    MSG_TYPE = 7

    def encode(self):
        return struct.pack("<I", 1)

    @classmethod
    def decode(cls, wire):
        if len(wire) < 4:
            raise TruncatedFrame("short")
        return cls()

def send(ch):
    wire = Ping().encode()
    ch.push(wire)
    return wire
"""}),
    Fixture("led001_charged_encode_ok", None, {"mod.py": """
import struct

class Ping:
    MSG_TYPE = 7

    def encode(self):
        return struct.pack("<I", 1)

    @classmethod
    def decode(cls, wire):
        if len(wire) < 4:
            raise TruncatedFrame("short")
        return cls()

def send(ch):
    wire = Ping().encode()
    ch.ledger.upload("weights", len(wire))
    return wire
"""}),
    Fixture("led002_unknown_category", "LED002", {"mod.py": """
def charge(ledger, wire):
    ledger.upload("knowledge", len(wire))
"""}),
    Fixture("led003_format_drift", "LED003", {"mod.py": """
import struct

class Pong:
    MSG_TYPE = 8

    def encode(self):
        return struct.pack("<IH", 1, 2)

    @classmethod
    def decode(cls, wire):
        a, = struct.unpack_from("<I", wire, 0)
        if a != 1:
            raise FrameError("bad")
        return cls()
"""}),
    Fixture("led004_no_frame_error_path", "LED004", {"mod.py": """
import struct

class Pong:
    MSG_TYPE = 9

    def encode(self):
        return struct.pack("<I", 1)

    @classmethod
    def decode(cls, wire):
        a, = struct.unpack("<I", wire)
        return cls()
"""}),
    # "@flcheck@" is rewritten to "flcheck" at materialization time so the
    # embedded directives don't fire when flcheck scans its own source
    Fixture("sup001_reasonless_suppression", "SUP001", {"mod.py": """
import jax

def f(key):
    x = jax.random.normal(key, (4,))
    y = jax.random.uniform(key, (4,))  # @flcheck@: disable=RNG002
    return x + y
"""}),
    Fixture("suppression_with_reason_ok", None, {"mod.py": """
import jax

def f(key):
    x = jax.random.normal(key, (4,))
    y = jax.random.uniform(key, (4,))  # @flcheck@: disable=RNG002 (A/B same-stream comparison)
    return x + y
"""}),
    Fixture("obs001_naked_clock", "OBS001", {"mod.py": """
import time

def f():
    t0 = time.perf_counter()
    return time.perf_counter() - t0
"""}),
    Fixture("obs001_from_import_clock", "OBS001", {"mod.py": """
from time import perf_counter

def f():
    return perf_counter()
"""}),
    Fixture("obs001_clock_inside_obs_ok", None, {"obs/timing.py": """
import time as _time

monotonic = _time.perf_counter

def now():
    \"\"\"The one wall clock (documented: obs/ is DOC001 scope too).\"\"\"
    return _time.perf_counter()
"""}),
    Fixture("obs001_span_without_with", "OBS001", {"mod.py": """
from repro import obs

def f():
    sp = obs.span("round")
    return sp
"""}),
    Fixture("obs001_span_with_ok", None, {"mod.py": """
from repro import obs

def f():
    with obs.span("round") as sp:
        sp.set(x=1)
"""}),
    Fixture("obs001_re_match_span_ok", None, {"mod.py": """
import re

def f(s):
    m = re.match(r"x+", s)
    return m.span()
"""}),
    Fixture("obs002_adhoc_bench_write", "OBS002", {
        "benchmarks/bad_bench.py": """
import json
import os

def run(report):
    out = os.path.join(os.path.dirname(__file__), "BENCH_x.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
"""}),
    Fixture("obs002_tainted_default_write", "OBS002", {
        "benchmarks/bad_bench.py": """
import json

def run(report, out_path="BENCH_x.json"):
    with open(out_path, "w") as f:
        json.dump(report, f)
"""}),
    Fixture("obs002_registry_writer_ok", None, {
        "benchmarks/good_bench.py": """
import os

from repro.obs.registry import write_bench

def run(report):
    out = os.path.join(os.path.dirname(__file__), "BENCH_x.json")
    write_bench(out, report)
    with open(out) as f:
        return f.read()
"""}),
    Fixture("obs002_outside_benchmarks_ok", None, {"tools/export.py": """
import json

def dump(report):
    with open("BENCH_x.json", "w") as f:
        json.dump(report, f)
"""}),
    Fixture("doc001_undocumented_transport_api", "DOC001", {
        "fl/transport/frames.py": """
class PingFrame:
    def encode(self):
        return b"ping"

def decode(wire):
    return wire
"""}),
    Fixture("doc001_documented_transport_api_ok", None, {
        "fl/transport/frames.py": """
class PingFrame:
    \"\"\"A one-byte liveness frame.\"\"\"

    def encode(self):
        \"\"\"Frame layout: the 4 ASCII bytes 'ping', no header.\"\"\"
        return b"ping"

    def _internal(self):
        return None

def decode(wire):
    \"\"\"Inverse of PingFrame.encode (no validation: fixed payload).\"\"\"
    return wire
"""}),
    Fixture("doc001_outside_contract_dirs_ok", None, {"core/maths.py": """
def undocumented_but_out_of_scope(x):
    return x + 1
"""}),
]


def run_self_test(verbose: bool = False) -> List[str]:
    """Run every fixture; returns a list of failure messages (empty = ok)."""
    failures: List[str] = []
    for fx in FIXTURES:
        with tempfile.TemporaryDirectory(prefix="flcheck_selftest_") as tmp:
            for rel, src in fx.files.items():
                path = os.path.join(tmp, rel)
                os.makedirs(os.path.dirname(path), exist_ok=True)
                with open(path, "w", encoding="utf-8") as f:
                    f.write(src.replace("@flcheck@", "flcheck"))
            findings = run_analysis([tmp], root=tmp)
        fired = {f.rule for f in findings}
        if fx.rule is None:
            if fired:
                failures.append(
                    f"{fx.name}: expected clean, got {sorted(fired)}")
        elif fx.rule not in fired:
            failures.append(
                f"{fx.name}: expected {fx.rule}, got {sorted(fired) or 'nothing'}")
        if verbose:
            status = "FAIL" if failures and failures[-1].startswith(fx.name) \
                else "ok"
            print(f"  {status:4s} {fx.name} -> {sorted(fired) or '[]'}")
    return failures
