"""flcheck core: file walking, findings, suppressions, baselines.

The checker is pure-stdlib (``ast`` + ``tokenize``-free line scanning) so it
runs in CI without jax installed and scans the full ``src/`` tree in well
under the 10 s budget tracked by ``benchmarks/run.py --only analysis``.

A *finding* is (rule, path, line, message, hint).  Baselines grandfather
existing findings by a line-shift-tolerant fingerprint: the hash covers the
rule ID, the repo-relative path and the stripped source text of the flagged
line (plus an occurrence counter for repeated identical lines), so pure
line-number churn does not invalidate the baseline.

Inline suppressions::

    some_code()  # flcheck: disable=RNG001 (same key on purpose: A/B engines)

The reason string in parentheses is mandatory; a reason-less directive is
itself a finding (SUP001) and is never honored.  A directive suppresses
matching findings on its own line or on the line directly below it (so it
can sit above a multi-line statement).
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*flcheck:\s*disable=(?P<rules>[A-Z0-9_,\s]+?)"
    r"(?:\s*\((?P<reason>.*)\))?\s*$"  # reason runs to the LAST ')'
)

SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules", ".venv"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # repo-relative, forward slashes
    line: int          # 1-based
    message: str
    hint: str = ""

    def render(self) -> str:
        s = f"{self.path}:{self.line}: {self.rule}: {self.message}"
        if self.hint:
            s += f"  [hint: {self.hint}]"
        return s

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Module:
    """One parsed source file plus everything rules need to inspect it."""
    path: str                 # repo-relative path with forward slashes
    abspath: str
    source: str
    lines: List[str]
    tree: ast.Module

    @property
    def in_kernels_dir(self) -> bool:
        parts = self.path.replace("\\", "/").split("/")
        return "kernels" in parts[:-1]


@dataclasses.dataclass
class Suppression:
    line: int
    rules: Tuple[str, ...]
    reason: str


def parse_suppressions(lines: Sequence[str]) -> List[Suppression]:
    out = []
    for i, raw in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(raw)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",") if r.strip())
        reason = (m.group("reason") or "").strip()
        out.append(Suppression(line=i, rules=rules, reason=reason))
    return out


def load_module(abspath: str, root: str) -> Optional[Module]:
    try:
        with open(abspath, "r", encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=abspath)
    except (OSError, SyntaxError, ValueError):
        return None
    rel = os.path.relpath(abspath, root).replace(os.sep, "/")
    return Module(path=rel, abspath=abspath, source=source,
                  lines=source.splitlines(), tree=tree)


def collect_files(paths: Sequence[str], root: str,
                  include_tests: bool = False) -> List[str]:
    """Expand paths (files or directories) into a sorted .py file list.

    Directories named in SKIP_DIRS are pruned.  Test files (under a
    ``tests`` directory or named ``test_*.py``) are skipped during
    directory walks unless ``include_tests`` — a file passed explicitly is
    always included.
    """
    files: List[str] = []
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap):
            if ap.endswith(".py"):
                files.append(ap)
            continue
        for dirpath, dirnames, filenames in os.walk(ap):
            dirnames[:] = sorted(d for d in dirnames if d not in SKIP_DIRS
                                 and not (not include_tests and d == "tests"))
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                if not include_tests and fn.startswith("test_"):
                    continue
                files.append(os.path.join(dirpath, fn))
    # de-dup, preserve order
    seen, out = set(), []
    for f in files:
        if f not in seen:
            seen.add(f)
            out.append(f)
    return out


def run_analysis(paths: Sequence[str], root: Optional[str] = None,
                 include_tests: bool = False) -> List[Finding]:
    """Run every registered rule over ``paths``; returns sorted findings.

    Suppressed findings (directive with reason on the same or previous
    line) are dropped; reason-less directives surface as SUP001.
    """
    from repro.analysis import (docs_rules, ledger, obs_rules, pallas_rules,
                                purity, rng)

    root = os.path.abspath(root or os.getcwd())
    modules: List[Module] = []
    for f in collect_files(paths, root, include_tests=include_tests):
        mod = load_module(f, root)
        if mod is not None:
            modules.append(mod)

    findings: List[Finding] = []
    for mod in modules:
        findings.extend(rng.check(mod))
        findings.extend(purity.check(mod))
        findings.extend(pallas_rules.check(mod))
        findings.extend(ledger.check(mod))
        findings.extend(obs_rules.check(mod))
        findings.extend(docs_rules.check(mod))
        findings = _apply_suppressions(mod, findings)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def _apply_suppressions(mod: Module, findings: List[Finding]) -> List[Finding]:
    sups = parse_suppressions(mod.lines)
    if not sups:
        return findings
    by_line: Dict[int, List[Suppression]] = {}
    for s in sups:
        by_line.setdefault(s.line, []).append(s)

    kept = []
    for f in findings:
        if f.path != mod.path:
            kept.append(f)
            continue
        # a directive on the finding line, or on the line directly above
        candidates = by_line.get(f.line, []) + by_line.get(f.line - 1, [])
        suppressed = any(
            f.rule in s.rules and s.reason for s in candidates
        )
        if not suppressed:
            kept.append(f)
    for s in sups:
        if not s.reason:
            kept.append(Finding(
                rule="SUP001", path=mod.path, line=s.line,
                message="flcheck suppression without a reason string",
                hint="write `# flcheck: disable=RULE (why this is safe)`"))
    return kept


# ---------------------------------------------------------------- baseline

def fingerprints(findings: Iterable[Finding], root: str) -> Dict[str, Finding]:
    """Map line-tolerant fingerprint -> finding.

    Fingerprint = sha1(rule | path | stripped flagged-line text | k) where k
    counts identical (rule, path, text) triples so two findings on
    duplicated lines stay distinct.
    """
    counts: Dict[Tuple[str, str, str], int] = {}
    out: Dict[str, Finding] = {}
    line_cache: Dict[str, List[str]] = {}
    for f in findings:
        if f.path not in line_cache:
            try:
                with open(os.path.join(root, f.path), "r", encoding="utf-8") as fh:
                    line_cache[f.path] = fh.read().splitlines()
            except OSError:
                line_cache[f.path] = []
        lines = line_cache[f.path]
        text = lines[f.line - 1].strip() if 0 < f.line <= len(lines) else ""
        key = (f.rule, f.path, text)
        k = counts.get(key, 0)
        counts[key] = k + 1
        h = hashlib.sha1(
            f"{f.rule}|{f.path}|{text}|{k}".encode("utf-8")).hexdigest()[:16]
        out[h] = f
    return out


def write_baseline(path: str, findings: Sequence[Finding], root: str) -> None:
    fps = fingerprints(findings, root)
    doc = {
        "version": 1,
        "tool": "flcheck",
        "findings": [
            {"fingerprint": fp, **f.to_json()} for fp, f in sorted(
                fps.items(), key=lambda kv: (kv[1].path, kv[1].line, kv[1].rule))
        ],
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def load_baseline(path: str) -> set:
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    return {e["fingerprint"] for e in doc.get("findings", [])}


def new_findings(findings: Sequence[Finding], baseline_fps: set,
                 root: str) -> List[Finding]:
    fps = fingerprints(findings, root)
    return [f for fp, f in fps.items() if fp not in baseline_fps]


# ------------------------------------------------------------- AST helpers

def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.random.split' for Attribute/Name chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def const_int(node: ast.AST, env: Optional[Dict[str, int]] = None
              ) -> Optional[int]:
    """Statically evaluate an int expression against a name->int env."""
    env = env or {}
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_int(node.operand, env)
        return -v if v is not None else None
    if isinstance(node, ast.BinOp):
        a, b = const_int(node.left, env), const_int(node.right, env)
        if a is None or b is None:
            return None
        try:
            if isinstance(node.op, ast.Mult):
                return a * b
            if isinstance(node.op, ast.Add):
                return a + b
            if isinstance(node.op, ast.Sub):
                return a - b
            if isinstance(node.op, ast.FloorDiv) and b:
                return a // b
            if isinstance(node.op, ast.Pow) and 0 <= b < 64:
                return a ** b
            if isinstance(node.op, ast.LShift) and 0 <= b < 64:
                return a << b
        except Exception:
            return None
    if isinstance(node, ast.Call):
        fn = dotted_name(node.func)
        if fn in ("min", "max") and node.args and not node.keywords:
            vals = [const_int(a, env) for a in node.args]
            if all(v is not None for v in vals):
                return (min if fn == "min" else max)(vals)
    return None
