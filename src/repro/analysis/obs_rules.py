"""Observability discipline rules (OBS001, OBS002).

``repro.obs.timing`` is the repo's ONE wall-clock: warmup-aware,
device-sync aware, monotonic (``time.time()`` steps under NTP and every
benchmark that read it measured something slightly different). OBS001
keeps it that way, and keeps trace spans balanced:

OBS001  (a) a direct stdlib clock read — ``time.time`` /
        ``perf_counter`` / ``monotonic`` / ``process_time`` (and their
        ``_ns`` twins), however imported — anywhere outside the
        ``repro/obs`` package; or
        (b) an ``obs.span(...)`` / ``obs.timed_block(...)`` opened
        without a ``with`` block, which would never close the span and
        corrupt the tracer's stack.

The span check is deliberately narrow — only ``obs.span`` /
``obs.timed_block`` attribute calls and bare names actually imported from
``repro.obs`` — so ``re.Match.span()`` and other unrelated ``.span``
methods never false-positive.

OBS002  an ad-hoc ``open(..., "w")`` of a ``BENCH_*.json`` file inside a
        ``benchmarks`` directory.  Every bench report goes through
        ``repro.obs.registry.write_bench`` — the one writer that also
        appends the fingerprinted record to
        ``experiments/bench_history.jsonl``; a raw ``json.dump`` silently
        drops that run from the regression trajectory that
        ``python -m repro.obs regress`` gates on.  The target is matched
        by a small taint walk: a string constant containing ``BENCH_``
        anywhere in the first ``open`` argument, or a bare name assigned
        from such an expression (``out = os.path.join(..., "BENCH_x.json")``)
        or defaulted to one in a function signature.  Read-mode opens are
        always fine.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.core import Finding, Module, dotted_name

# stdlib clock attributes that only repro.obs may read
CLOCK_ATTRS = {"time", "perf_counter", "monotonic", "process_time",
               "perf_counter_ns", "monotonic_ns", "process_time_ns",
               "clock"}
SPAN_OPENERS = {"span", "timed_block"}


def _in_obs_package(mod: Module) -> bool:
    return "obs" in mod.path.replace("\\", "/").split("/")[:-1]


def _time_aliases(mod: Module) -> Set[str]:
    """Names the stdlib ``time`` module is bound to (``time``, ``_time``,
    ...)."""
    out: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "time":
                    out.add(a.asname or a.name)
    return out


def _clock_names(mod: Module) -> Set[str]:
    """Bare names bound to stdlib clocks via ``from time import ...``."""
    out: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom):
            if node.module == "time" and node.level == 0:
                for a in node.names:
                    if a.name in CLOCK_ATTRS:
                        out.add(a.asname or a.name)
    return out


def _obs_span_names(mod: Module) -> Set[str]:
    """Bare names bound to span openers via ``from repro.obs import ...``."""
    out: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom):
            if node.module in ("repro.obs", "repro.obs.tracer") \
                    and node.level == 0:
                for a in node.names:
                    if a.name in SPAN_OPENERS:
                        out.add(a.asname or a.name)
    return out


def _with_context_calls(tree: ast.Module) -> Set[int]:
    """ids of Call nodes that are ``with`` context expressions."""
    out: Set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if isinstance(item.context_expr, ast.Call):
                    out.add(id(item.context_expr))
    return out


def _in_benchmarks_dir(mod: Module) -> bool:
    return "benchmarks" in mod.path.replace("\\", "/").split("/")[:-1]


def _contains_bench_const(node: ast.AST) -> bool:
    """Any string constant containing 'BENCH_' anywhere under ``node``."""
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, str) \
                and "BENCH_" in n.value:
            return True
    return False


def _bench_tainted_names(tree: ast.Module) -> Set[str]:
    """Bare names bound (by assignment or signature default) to an
    expression mentioning a BENCH_ path constant."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and _contains_bench_const(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(node, ast.AnnAssign) and node.value is not None \
                and isinstance(node.target, ast.Name) \
                and _contains_bench_const(node.value):
            out.add(node.target.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            pos = args.posonlyargs + args.args
            for a, d in zip(pos[len(pos) - len(args.defaults):],
                            args.defaults):
                if d is not None and _contains_bench_const(d):
                    out.add(a.arg)
            for a, d in zip(args.kwonlyargs, args.kw_defaults):
                if d is not None and _contains_bench_const(d):
                    out.add(a.arg)
    return out


def _open_write_mode(node: ast.Call) -> bool:
    """True when this ``open(...)`` call's mode writes (w/a/x/+)."""
    mode = None
    if len(node.args) >= 2:
        mode = node.args[1]
    for kw in node.keywords:
        if kw.arg == "mode":
            mode = kw.value
    if mode is None:
        return False                       # default 'r'
    if isinstance(mode, ast.Constant) and isinstance(mode.value, str):
        return bool(set(mode.value) & set("wax+"))
    return True                            # dynamic mode: assume the worst


def _check_bench_writer(mod: Module) -> List[Finding]:
    if not _in_benchmarks_dir(mod):
        return []
    tainted = _bench_tainted_names(mod.tree)
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "open" and node.args):
            continue
        target = node.args[0]
        is_bench = _contains_bench_const(target) or (
            isinstance(target, ast.Name) and target.id in tainted)
        if is_bench and _open_write_mode(node):
            findings.append(Finding(
                rule="OBS002", path=mod.path, line=node.lineno,
                message="ad-hoc write of a BENCH_*.json bypasses the "
                        "bench run-registry",
                hint="use repro.obs.registry.write_bench(path, report) — "
                     "it writes the JSON and appends the fingerprinted "
                     "record to experiments/bench_history.jsonl"))
    return findings


def check(mod: Module) -> List[Finding]:
    if _in_obs_package(mod):
        return []
    findings: List[Finding] = list(_check_bench_writer(mod))
    time_aliases = _time_aliases(mod)
    clock_names = _clock_names(mod)
    span_names = _obs_span_names(mod)
    with_calls = _with_context_calls(mod.tree)

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        # (a) direct stdlib clock reads
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in CLOCK_ATTRS \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id in time_aliases:
            findings.append(Finding(
                rule="OBS001", path=mod.path, line=node.lineno,
                message=f"direct stdlib clock read "
                        f"`{name}()` outside repro.obs",
                hint="use repro.obs.timing.monotonic (or timeit for "
                     "warmup-aware benchmarking)"))
            continue
        if isinstance(node.func, ast.Name) and node.func.id in clock_names:
            findings.append(Finding(
                rule="OBS001", path=mod.path, line=node.lineno,
                message=f"direct stdlib clock read `{node.func.id}()` "
                        f"outside repro.obs",
                hint="use repro.obs.timing.monotonic (or timeit for "
                     "warmup-aware benchmarking)"))
            continue
        # (b) span opened without `with`
        is_span_call = (
            name in ("obs.span", "obs.timed_block")
            or (isinstance(node.func, ast.Name)
                and node.func.id in span_names))
        if is_span_call and id(node) not in with_calls:
            findings.append(Finding(
                rule="OBS001", path=mod.path, line=node.lineno,
                message=f"`{name}(...)` opened outside a `with` block",
                hint="spans must close on the tracer's stack: "
                     "`with obs.span(...) as sp:`"))
    return findings
