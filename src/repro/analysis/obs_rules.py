"""Observability discipline rule (OBS001).

``repro.obs.timing`` is the repo's ONE wall-clock: warmup-aware,
device-sync aware, monotonic (``time.time()`` steps under NTP and every
benchmark that read it measured something slightly different). OBS001
keeps it that way, and keeps trace spans balanced:

OBS001  (a) a direct stdlib clock read — ``time.time`` /
        ``perf_counter`` / ``monotonic`` / ``process_time`` (and their
        ``_ns`` twins), however imported — anywhere outside the
        ``repro/obs`` package; or
        (b) an ``obs.span(...)`` / ``obs.timed_block(...)`` opened
        without a ``with`` block, which would never close the span and
        corrupt the tracer's stack.

The span check is deliberately narrow — only ``obs.span`` /
``obs.timed_block`` attribute calls and bare names actually imported from
``repro.obs`` — so ``re.Match.span()`` and other unrelated ``.span``
methods never false-positive.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.core import Finding, Module, dotted_name

# stdlib clock attributes that only repro.obs may read
CLOCK_ATTRS = {"time", "perf_counter", "monotonic", "process_time",
               "perf_counter_ns", "monotonic_ns", "process_time_ns",
               "clock"}
SPAN_OPENERS = {"span", "timed_block"}


def _in_obs_package(mod: Module) -> bool:
    return "obs" in mod.path.replace("\\", "/").split("/")[:-1]


def _time_aliases(mod: Module) -> Set[str]:
    """Names the stdlib ``time`` module is bound to (``time``, ``_time``,
    ...)."""
    out: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "time":
                    out.add(a.asname or a.name)
    return out


def _clock_names(mod: Module) -> Set[str]:
    """Bare names bound to stdlib clocks via ``from time import ...``."""
    out: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom):
            if node.module == "time" and node.level == 0:
                for a in node.names:
                    if a.name in CLOCK_ATTRS:
                        out.add(a.asname or a.name)
    return out


def _obs_span_names(mod: Module) -> Set[str]:
    """Bare names bound to span openers via ``from repro.obs import ...``."""
    out: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom):
            if node.module in ("repro.obs", "repro.obs.tracer") \
                    and node.level == 0:
                for a in node.names:
                    if a.name in SPAN_OPENERS:
                        out.add(a.asname or a.name)
    return out


def _with_context_calls(tree: ast.Module) -> Set[int]:
    """ids of Call nodes that are ``with`` context expressions."""
    out: Set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if isinstance(item.context_expr, ast.Call):
                    out.add(id(item.context_expr))
    return out


def check(mod: Module) -> List[Finding]:
    if _in_obs_package(mod):
        return []
    findings: List[Finding] = []
    time_aliases = _time_aliases(mod)
    clock_names = _clock_names(mod)
    span_names = _obs_span_names(mod)
    with_calls = _with_context_calls(mod.tree)

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        # (a) direct stdlib clock reads
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in CLOCK_ATTRS \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id in time_aliases:
            findings.append(Finding(
                rule="OBS001", path=mod.path, line=node.lineno,
                message=f"direct stdlib clock read "
                        f"`{name}()` outside repro.obs",
                hint="use repro.obs.timing.monotonic (or timeit for "
                     "warmup-aware benchmarking)"))
            continue
        if isinstance(node.func, ast.Name) and node.func.id in clock_names:
            findings.append(Finding(
                rule="OBS001", path=mod.path, line=node.lineno,
                message=f"direct stdlib clock read `{node.func.id}()` "
                        f"outside repro.obs",
                hint="use repro.obs.timing.monotonic (or timeit for "
                     "warmup-aware benchmarking)"))
            continue
        # (b) span opened without `with`
        is_span_call = (
            name in ("obs.span", "obs.timed_block")
            or (isinstance(node.func, ast.Name)
                and node.func.id in span_names))
        if is_span_call and id(node) not in with_calls:
            findings.append(Finding(
                rule="OBS001", path=mod.path, line=node.lineno,
                message=f"`{name}(...)` opened outside a `with` block",
                hint="spans must close on the tracer's stack: "
                     "`with obs.span(...) as sp:`"))
    return findings
