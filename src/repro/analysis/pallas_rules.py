"""Pallas tiling rules (PAL001–PAL004).

TPU tiles are (sublane, lane) = (8, 128) for f32 (see
/opt/skills/guides/pallas_guide.md): the last BlockSpec dim should be a
multiple of 128 and the second-to-last a multiple of 8, or exactly 1
(broadcast row/column — Mosaic pads a single row to one tile, which is the
cheap, intentional case).  Dims that cannot be resolved statically (runtime
shapes) are skipped, never guessed.

PAL001  lane (last) block dim resolved and not 1 or a multiple of 128
PAL002  sublane (second-to-last) block dim resolved and not 1 or a
        multiple of 8
PAL003  estimated VMEM residency of one grid step exceeds the ~16 MiB/core
        budget (only when every block dim resolves; in/out blocks charged
        twice for pipeline double-buffering)
PAL004  a ``pl.pallas_call`` wrapper in ``kernels/`` has no interpret-mode
        oracle ``<wrapper>_ref`` in the sibling ``ref.py``

Dims are resolved against integer literals, module constants, enclosing
function defaults, and straight-line local assignments (``min``/``max`` and
arithmetic of resolved values fold; anything touching a runtime shape makes
the name unresolvable).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Tuple

from repro.analysis.core import Finding, Module, const_int, dotted_name, \
    load_module

LANE = 128
SUBLANE = 8
VMEM_BUDGET = 16 * 1024 * 1024
DTYPE_BYTES = {"float32": 4, "f32": 4, "int32": 4, "uint32": 4,
               "bfloat16": 2, "float16": 2, "int8": 1, "uint8": 1,
               "bool_": 1, "float64": 8, "int64": 8}

_REF_CACHE: Dict[str, Optional[set]] = {}


def _ref_oracle_names(mod: Module) -> Optional[set]:
    """Top-level def names in the ref.py next to this kernels module."""
    ref_path = os.path.join(os.path.dirname(mod.abspath), "ref.py")
    if ref_path not in _REF_CACHE:
        if not os.path.isfile(ref_path):
            _REF_CACHE[ref_path] = None
        else:
            ref_mod = load_module(ref_path, os.path.dirname(ref_path))
            _REF_CACHE[ref_path] = None if ref_mod is None else {
                n.name for n in ref_mod.tree.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    return _REF_CACHE[ref_path]


def _module_env(mod: Module) -> Dict[str, int]:
    env: Dict[str, int] = {}
    for stmt in mod.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            v = const_int(stmt.value, env)
            if v is not None:
                env[stmt.targets[0].id] = v
    return env


def _fn_env(fndef: ast.FunctionDef, base: Dict[str, int],
            upto_line: int) -> Dict[str, int]:
    env = dict(base)
    args = fndef.args
    pos = args.posonlyargs + args.args
    for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        v = const_int(d, env)
        if v is not None:
            env[a.arg] = v
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is not None:
            v = const_int(d, env)
            if v is not None:
                env[a.arg] = v
    for node in ast.walk(fndef):
        if isinstance(node, ast.Assign) and node.lineno < upto_line \
                and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            v = const_int(node.value, env)
            if v is not None:
                env[name] = v
            else:
                env.pop(name, None)  # reassigned to something non-static
    return env


def _block_specs(call: ast.Call) -> List[Tuple[str, ast.Call]]:
    """(role, BlockSpec-call) pairs from in_specs/out_specs keywords."""
    out: List[Tuple[str, ast.Call]] = []
    for kw in call.keywords:
        if kw.arg not in ("in_specs", "out_specs"):
            continue
        role = "in" if kw.arg == "in_specs" else "out"
        exprs = kw.value.elts if isinstance(kw.value, (ast.List, ast.Tuple)) \
            else [kw.value]
        for e in exprs:
            if isinstance(e, ast.Call) and (dotted_name(e.func) or "") \
                    .endswith("BlockSpec"):
                out.append((role, e))
    return out


def _scratch_shapes(call: ast.Call) -> List[ast.Call]:
    for kw in call.keywords:
        if kw.arg == "scratch_shapes":
            exprs = kw.value.elts if isinstance(kw.value,
                                                (ast.List, ast.Tuple)) \
                else [kw.value]
            return [e for e in exprs if isinstance(e, ast.Call)]
    return []


def _shape_dims(shape: ast.expr, env: Dict[str, int]
                ) -> Optional[List[Optional[int]]]:
    if not isinstance(shape, (ast.Tuple, ast.List)):
        return None
    return [const_int(e, env) for e in shape.elts]


def _dtype_bytes(node: Optional[ast.expr]) -> int:
    if node is None:
        return 4
    d = dotted_name(node) or ""
    for name, size in DTYPE_BYTES.items():
        if d.endswith(name):
            return size
    return 4


def _check_alignment(mod: Module, dims: List[Optional[int]], line: int,
                     what: str, findings: List[Finding]) -> None:
    if not dims:
        return
    lane = dims[-1]
    if lane is not None and lane != 1 and lane % LANE != 0:
        findings.append(Finding(
            rule="PAL001", path=mod.path, line=line,
            message=(f"{what} lane (last) dim {lane} is not a multiple of "
                     f"{LANE} — Mosaic pads every tile, wasting VMEM and "
                     "vector lanes"),
            hint=f"pad the block to a multiple of {LANE} and mask the tail "
                 "(compare against an iota like the kmeans kernels)"))
    if len(dims) >= 2:
        sub = dims[-2]
        if sub is not None and sub != 1 and sub % SUBLANE != 0:
            findings.append(Finding(
                rule="PAL002", path=mod.path, line=line,
                message=(f"{what} sublane dim {sub} is not 1 or a multiple "
                         f"of {SUBLANE} (f32 tile is ({SUBLANE}, {LANE}))"),
                hint="round the sublane dim up to 8 with a masked tail, or "
                     "use a single broadcast row"))


def check(mod: Module) -> List[Finding]:
    if "pallas_call" not in mod.source:
        return []
    findings: List[Finding] = []
    menv = _module_env(mod)
    oracle_names = _ref_oracle_names(mod) if mod.in_kernels_dir else None

    for top in mod.tree.body:
        if not isinstance(top, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        has_pallas = False
        for node in ast.walk(top):
            if not isinstance(node, ast.Call):
                continue
            if not (dotted_name(node.func) or "").endswith("pallas_call"):
                continue
            has_pallas = True
            env = _fn_env(top, menv, node.lineno)
            total = 0
            complete = True
            for role, spec in _block_specs(node):
                dims = _shape_dims(spec.args[0], env) if spec.args else None
                if dims is None:
                    complete = False
                    continue
                _check_alignment(mod, dims, spec.lineno,
                                 f"{role}_spec block", findings)
                if any(d is None for d in dims):
                    complete = False
                else:
                    nelem = 1
                    for d in dims:
                        nelem *= d
                    total += nelem * 4 * 2  # double-buffered pipeline stage
            for sc in _scratch_shapes(node):
                dims = _shape_dims(sc.args[0], env) if sc.args else None
                if dims is None:
                    complete = False
                    continue
                _check_alignment(mod, dims, sc.lineno, "scratch", findings)
                if any(d is None for d in dims):
                    complete = False
                else:
                    nelem = 1
                    for d in dims:
                        nelem *= d
                    total += nelem * _dtype_bytes(
                        sc.args[1] if len(sc.args) > 1 else None)
            if complete and total > VMEM_BUDGET:
                findings.append(Finding(
                    rule="PAL003", path=mod.path, line=node.lineno,
                    message=(f"estimated VMEM residency {total // 1024} KiB "
                             f"exceeds the {VMEM_BUDGET // (1024 * 1024)} "
                             "MiB/core budget"),
                    hint="shrink block dims or move the reduction into the "
                         "grid (two-phase pattern like quantize_affine)"))
        if has_pallas and mod.in_kernels_dir:
            base = top.name
            if base.endswith("_kernel"):
                base = base[: -len("_kernel")]
            want = f"{base}_ref"
            if not oracle_names or want not in oracle_names:
                findings.append(Finding(
                    rule="PAL004", path=mod.path, line=top.lineno,
                    message=(f"pallas_call wrapper `{top.name}` has no "
                             f"interpret-mode oracle `{want}` in the "
                             "sibling ref.py"),
                    hint="add a jnp reference implementation and assert "
                         "bit-identity under interpret=True in tests"))
    return findings
