"""flcheck CLI.

    python -m repro.analysis [paths...]            # scan (default: src benchmarks)
    python -m repro.analysis --against-baseline analysis_baseline.json
    python -m repro.analysis --write-baseline analysis_baseline.json
    python -m repro.analysis --self-test
    python -m repro.analysis --list-rules

Exit codes: 0 clean (or nothing new vs. baseline), 1 findings / self-test
failure, 2 usage error.  With no --against/--write flag, an
``analysis_baseline.json`` in the working directory is used automatically
when present.  SUP001 (reason-less suppression) is never grandfathered.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis import RULE_IDS, core
from repro.obs.timing import monotonic


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="flcheck: RNG / tracer / Pallas-tiling / ledger "
                    "static checks")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories (default: src benchmarks)")
    ap.add_argument("--against-baseline", metavar="FILE",
                    help="fail only on findings not in FILE")
    ap.add_argument("--write-baseline", metavar="FILE",
                    help="write current findings to FILE and exit 0")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    ap.add_argument("--self-test", action="store_true",
                    help="run the embedded known-bad/known-good fixtures")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--include-tests", action="store_true",
                    help="also scan tests/ directories and test_*.py files")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any analysis_baseline.json in cwd")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in RULE_IDS:
            print(r)
        return 0

    if args.self_test:
        from repro.analysis.selftest import FIXTURES, run_self_test
        t0 = monotonic()
        failures = run_self_test(verbose=not args.as_json)
        dt = monotonic() - t0
        print(f"self-test: {len(FIXTURES) - len(failures)}/{len(FIXTURES)} "
              f"fixtures ok in {dt:.2f}s")
        for msg in failures:
            print(f"  FAIL {msg}", file=sys.stderr)
        return 1 if failures else 0

    root = os.getcwd()
    paths = args.paths or ["src", "benchmarks"]
    for p in paths:
        ap_ = p if os.path.isabs(p) else os.path.join(root, p)
        if not os.path.exists(ap_):
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    t0 = monotonic()
    findings = core.run_analysis(paths, root=root,
                                 include_tests=args.include_tests)
    dt = monotonic() - t0

    if args.write_baseline:
        # suppressionless-reason findings must never be grandfathered
        base = [f for f in findings if f.rule != "SUP001"]
        core.write_baseline(args.write_baseline, base, root)
        print(f"wrote {len(base)} finding(s) to {args.write_baseline} "
              f"({dt:.2f}s scan)")
        sup = [f for f in findings if f.rule == "SUP001"]
        for f in sup:
            print(f.render(), file=sys.stderr)
        return 1 if sup else 0

    baseline_path = args.against_baseline
    if baseline_path is None and not args.no_baseline:
        default = os.path.join(root, "analysis_baseline.json")
        if os.path.isfile(default):
            baseline_path = default

    if baseline_path:
        try:
            baseline = core.load_baseline(baseline_path)
        except (OSError, ValueError) as e:
            print(f"error: cannot read baseline {baseline_path}: {e}",
                  file=sys.stderr)
            return 2
        report = core.new_findings(
            [f for f in findings if f.rule != "SUP001"], baseline, root)
        report += [f for f in findings if f.rule == "SUP001"]
        label = "new finding(s) vs baseline"
        grandfathered = len(findings) - len(report)
    else:
        report = findings
        label = "finding(s)"
        grandfathered = 0

    report.sort(key=lambda f: (f.path, f.line, f.rule))
    if args.as_json:
        print(json.dumps([f.to_json() for f in report], indent=1))
    else:
        for f in report:
            print(f.render())
    extra = f", {grandfathered} grandfathered" if grandfathered else ""
    print(f"flcheck: {len(report)} {label}{extra} "
          f"({dt:.2f}s scan)", file=sys.stderr)
    return 1 if report else 0


if __name__ == "__main__":
    sys.exit(main())
