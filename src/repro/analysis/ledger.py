"""Ledger / wire-format audit rules (LED001–LED004).

The paper's 1.6% knowledge-exchange claim is only meaningful if every byte
that crosses the simulated wire is charged to the ``CommLedger`` — these
rules make the charging byte-true at review time (the round-0 broadcast and
bf16-billed-as-f32 bugs both shipped before flcheck existed).

LED001  a ``Message`` frame ``encode()`` call site whose enclosing function
        never reaches a ``ledger.upload``/``download`` charge (directly or
        through same-module calls like ``FaultyChannel._deliver``)
LED002  a ledger charge with a category literal outside the known set
        {metadata, weights, retransmit, duplicate}
LED003  a ``Message`` subclass whose encode/decode struct format strings
        are not symmetric (field-list drift — one side packs what the
        other doesn't unpack)
LED004  a ``Message`` subclass ``decode`` that never raises (directly or
        via same-module helpers) a typed ``FrameError``
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Module, dotted_name

KNOWN_CATEGORIES = {"metadata", "weights", "retransmit", "duplicate"}
CATEGORY_CONSTANTS = {"RETRANSMIT": "retransmit", "DUPLICATE": "duplicate"}
MESSAGE_CLASS_NAMES = {"WeightBroadcast", "UpperUpdate", "SelectedKnowledge"}
FRAME_ERRORS = {"FrameError", "TruncatedFrame", "BadMagic", "BadVersion",
                "ChecksumMismatch", "WrongMessageType", "UnknownCodec",
                "UnknownDtype", "LengthMismatch"}
STRUCT_FMT_RE = re.compile(r"^[@=<>!]?[\dxcbB?hHiIlLqQnNefdspP]+$")
MAX_DEPTH = 4


def _message_classes(mod: Module) -> Set[str]:
    names = set(MESSAGE_CLASS_NAMES)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef):
            for stmt in node.body:
                targets = []
                if isinstance(stmt, ast.Assign):
                    targets = stmt.targets
                elif isinstance(stmt, ast.AnnAssign):
                    targets = [stmt.target]
                if any(isinstance(t, ast.Name) and t.id == "MSG_TYPE"
                       for t in targets):
                    names.add(node.name)
    return names


def _is_charge(call: ast.Call) -> bool:
    if not isinstance(call.func, ast.Attribute):
        return False
    if call.func.attr not in ("upload", "download"):
        return False
    recv = dotted_name(call.func.value) or ""
    return recv == "ledger" or recv.endswith(".ledger") or "ledger" in \
        recv.split(".")[-1].lower()


class _CallGraph:
    """Same-module 'does this function reach a ledger charge' oracle."""

    def __init__(self, mod: Module):
        self.fns: Dict[str, ast.AST] = {}
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.fns[node.name] = node
            elif isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        # method reachable both as self.m and Class.m;
                        # bare name kept too for cls-style calls
                        self.fns.setdefault(sub.name, sub)
                        self.fns[f"{node.name}.{sub.name}"] = sub
        self._memo: Dict[Tuple[int, str], bool] = {}

    def reaches(self, fn: ast.AST, predicate, depth: int = 0,
                seen: Optional[Set[int]] = None) -> bool:
        seen = seen if seen is not None else set()
        if id(fn) in seen or depth > MAX_DEPTH:
            return False
        seen.add(id(fn))
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and predicate(node):
                return True
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            if not d:
                continue
            callee = None
            if d in self.fns:
                callee = self.fns[d]
            else:
                last = d.split(".")[-1]
                if d.startswith(("self.", "cls.")) and last in self.fns:
                    callee = self.fns[last]
            if callee is not None and self.reaches(callee, predicate,
                                                   depth + 1, seen):
                return True
        return False

    def reaches_raise(self, fn: ast.AST, error_names: Set[str],
                      depth: int = 0,
                      seen: Optional[Set[int]] = None) -> bool:
        seen = seen if seen is not None else set()
        if id(fn) in seen or depth > MAX_DEPTH:
            return False
        seen.add(id(fn))
        for node in ast.walk(fn):
            if isinstance(node, ast.Raise) and node.exc is not None:
                exc = node.exc
                name = dotted_name(exc.func if isinstance(exc, ast.Call)
                                   else exc)
                if name and name.split(".")[-1] in error_names:
                    return True
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            if not d:
                continue
            callee = self.fns.get(d) or (
                self.fns.get(d.split(".")[-1])
                if d.startswith(("self.", "cls.", "_")) or "." not in d
                else None)
            if callee is None:
                last = d.split(".")[-1]
                callee = self.fns.get(last)
            if callee is not None and self.reaches_raise(
                    callee, error_names, depth + 1, seen):
                return True
        return False


def _frame_error_names(mod: Module) -> Set[str]:
    names = set(FRAME_ERRORS)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom) and node.module \
                and node.module.endswith("errors"):
            names.update(a.asname or a.name for a in node.names)
        elif isinstance(node, ast.ClassDef):
            bases = {dotted_name(b) for b in node.bases}
            if any(b and b.split(".")[-1] in names for b in bases):
                names.add(node.name)
    return names


def _struct_formats(fn: ast.AST) -> List[str]:
    fmts = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        d = dotted_name(node.func) or ""
        tail = d.split(".")[-1]
        if tail not in ("pack", "unpack", "unpack_from", "pack_into",
                        "calcsize", "Struct"):
            continue
        a0 = node.args[0]
        if isinstance(a0, ast.Constant) and isinstance(a0.value, str) \
                and STRUCT_FMT_RE.match(a0.value.strip()):
            fmts.append(a0.value.strip())
    return sorted(fmts)


def _enclosing_functions(mod: Module) -> List[Tuple[ast.AST, List[ast.AST]]]:
    """(function, [calls inside it, excluding nested defs' bodies])."""
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.append(node)
    return out


def check(mod: Module) -> List[Finding]:
    findings: List[Finding] = []
    msg_classes = _message_classes(mod)
    graph = _CallGraph(mod)

    # ---- LED001: frame encode must reach a charge -----------------------
    owner: Dict[int, ast.AST] = {}  # id(call) -> enclosing function
    for fn in _enclosing_functions(mod):
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                owner.setdefault(id(node), fn)

    for fn in [None] + _enclosing_functions(mod):
        body = mod.tree if fn is None else fn
        msg_vars: Set[str] = set()
        for node in ast.walk(body):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                ctor = dotted_name(node.value.func)
                if ctor and ctor.split(".")[-1] in msg_classes:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            msg_vars.add(t.id)
        for node in ast.walk(body):
            if not isinstance(node, ast.Call):
                continue
            if not isinstance(node.func, ast.Attribute) \
                    or node.func.attr != "encode":
                continue
            enc_fn = owner.get(id(node))
            if (fn is None) != (enc_fn is None) or \
                    (fn is not None and enc_fn is not fn):
                continue  # count each call site exactly once, in its owner
            recv = node.func.value
            is_frame = False
            if isinstance(recv, ast.Call):
                ctor = dotted_name(recv.func)
                is_frame = bool(ctor) and ctor.split(".")[-1] in msg_classes
            elif isinstance(recv, ast.Name):
                is_frame = recv.id in msg_vars
            if not is_frame:
                continue
            charged = enc_fn is not None and graph.reaches(
                enc_fn, _is_charge)
            if not charged:
                where = getattr(enc_fn, "name", "<module>")
                findings.append(Finding(
                    rule="LED001", path=mod.path, line=node.lineno,
                    message=("frame encode() in `%s` never reaches a "
                             "CommLedger charge — these wire bytes are "
                             "invisible to the accounting" % where),
                    hint="charge len(wire) via ledger.upload/download (or "
                         "route through Channel, which charges exactly "
                         "the encoded frame length)"))

    # ---- LED002: charge categories --------------------------------------
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and _is_charge(node)):
            continue
        if not node.args:
            continue
        cat = node.args[0]
        value: Optional[str] = None
        if isinstance(cat, ast.Constant) and isinstance(cat.value, str):
            value = cat.value
        elif isinstance(cat, ast.Name) and cat.id in CATEGORY_CONSTANTS:
            value = CATEGORY_CONSTANTS[cat.id]
        elif (d := dotted_name(cat)) and d.split(".")[-1] in \
                CATEGORY_CONSTANTS:
            value = CATEGORY_CONSTANTS[d.split(".")[-1]]
        if value is not None and value not in KNOWN_CATEGORIES:
            findings.append(Finding(
                rule="LED002", path=mod.path, line=node.lineno,
                message=(f"ledger charge category '{value}' is not one of "
                         f"{sorted(KNOWN_CATEGORIES)} — BENCH_comms/"
                         "BENCH_faults reports will not account for it"),
                hint="use an existing category or register the new one in "
                     "repro.fl.comms and the benchmark reports"))

    # ---- LED003 / LED004: Message subclass contracts --------------------
    error_names = _frame_error_names(mod)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        has_msg_type = any(
            isinstance(s, (ast.Assign, ast.AnnAssign)) and any(
                isinstance(t, ast.Name) and t.id == "MSG_TYPE"
                for t in (s.targets if isinstance(s, ast.Assign)
                          else [s.target]))
            for s in node.body)
        if not has_msg_type:
            continue
        methods = {s.name: s for s in node.body
                   if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))}
        enc, dec = methods.get("encode"), methods.get("decode")
        if enc is not None and dec is not None:
            fe, fd = _struct_formats(enc), _struct_formats(dec)
            if fe and fd and fe != fd:
                findings.append(Finding(
                    rule="LED003", path=mod.path, line=node.lineno,
                    message=(f"`{node.name}` encode/decode struct formats "
                             f"differ: encode packs {fe}, decode unpacks "
                             f"{fd} — field lists have drifted"),
                    hint="keep pack/unpack format strings in mirrored "
                         "order; share one module-level struct.Struct"))
        if dec is not None and not graph.reaches_raise(dec, error_names):
            findings.append(Finding(
                rule="LED004", path=mod.path, line=dec.lineno,
                message=(f"`{node.name}.decode` has no typed FrameError "
                         "path — malformed wires will surface as raw "
                         "struct.error/IndexError"),
                hint="validate header/lengths and raise "
                     "repro.fl.transport.errors types (TruncatedFrame, "
                     "WrongMessageType, ...)"))
    return findings
