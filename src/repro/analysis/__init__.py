"""repro.analysis — flcheck, the repo-native static checker.

Rule families (see each module's docstring for the full contract):

* RNG001–RNG004  PRNG key discipline            (repro.analysis.rng)
* PUR001–PUR004  tracer safety in jitted code    (repro.analysis.purity)
* PAL001–PAL004  Pallas BlockSpec tiling + VMEM  (repro.analysis.pallas_rules)
* LED001–LED004  byte-true ledger / wire audit   (repro.analysis.ledger)
* OBS001–OBS002  one-wall-clock + balanced spans,
                 write_bench-only BENCH writes    (repro.analysis.obs_rules)
* DOC001         public API docstrings on the
                 transport/obs contract surfaces  (repro.analysis.docs_rules)
* SUP001         reason-less inline suppression  (repro.analysis.core)

Run ``python -m repro.analysis src benchmarks`` (exit 0 against the
checked-in ``analysis_baseline.json``) or ``--self-test`` for the embedded
known-bad/known-good fixtures.
"""
from repro.analysis.core import (Finding, Module, fingerprints,
                                 load_baseline, new_findings, run_analysis,
                                 write_baseline)

RULE_IDS = (
    "RNG001", "RNG002", "RNG003", "RNG004",
    "PUR001", "PUR002", "PUR003", "PUR004",
    "PAL001", "PAL002", "PAL003", "PAL004",
    "LED001", "LED002", "LED003", "LED004",
    "OBS001", "OBS002",
    "DOC001",
    "SUP001",
)

__all__ = ["Finding", "Module", "RULE_IDS", "fingerprints", "load_baseline",
           "new_findings", "run_analysis", "write_baseline"]
