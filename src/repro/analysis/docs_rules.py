"""Documentation discipline rule (DOC001).

``repro.fl.transport`` and ``repro.obs`` are the repo's two CONTRACT
surfaces: the wire format (frame layouts, the v2 flags byte + CRC trailer,
byte-true ledger charging) and the observability API (span taxonomy,
``write_bench``'s history contract). Those contracts live in docstrings —
docs/architecture.md points at them instead of restating them — so an
undocumented public symbol there is a hole in the spec, not a style nit.

DOC001  a public (non-underscore) module-level class or function — or a
        public method of a public class — without a docstring, in any
        module under an ``fl/transport`` or ``obs`` package directory.
        Private helpers (leading ``_``, including dunder methods) and
        nested functions are exempt; other packages are out of scope (the
        rule polices the contract surfaces, not the whole tree).

Existing gaps are grandfathered by ``analysis_baseline.json`` like every
other rule — only NEW undocumented public API fails CI.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import Finding, Module

RULE = "DOC001"

_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _in_scope(mod: Module) -> bool:
    """True for modules under an ``fl/transport`` or ``obs`` directory."""
    dirs = mod.path.replace("\\", "/").split("/")[:-1]
    if "obs" in dirs:
        return True
    return "transport" in dirs and "fl" in dirs


def _public(name: str) -> bool:
    return not name.startswith("_")


def _finding(mod: Module, node: ast.AST, kind: str, name: str) -> Finding:
    return Finding(
        rule=RULE, path=mod.path, line=node.lineno,
        message=f"public {kind} '{name}' has no docstring",
        hint="document the contract (frame layout / span semantics / "
             "charging rule) or rename with a leading '_' if internal")


def check(mod: Module) -> List[Finding]:
    """Missing-docstring findings for one module (empty out of scope)."""
    if not _in_scope(mod):
        return []
    out: List[Finding] = []
    for node in mod.tree.body:
        if isinstance(node, _DEFS) and _public(node.name):
            if ast.get_docstring(node) is None:
                out.append(_finding(mod, node, "function", node.name))
        elif isinstance(node, ast.ClassDef) and _public(node.name):
            if ast.get_docstring(node) is None:
                out.append(_finding(mod, node, "class", node.name))
            for sub in node.body:
                if isinstance(sub, _DEFS) and _public(sub.name) \
                        and ast.get_docstring(sub) is None:
                    out.append(_finding(
                        mod, sub, "method", f"{node.name}.{sub.name}"))
    return out
