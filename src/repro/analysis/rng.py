"""RNG discipline rules (RNG001–RNG004).

JAX PRNG hygiene: a key is *consumed* by ``jax.random.split`` or by any
sampler; after consumption the same variable must not be fed to another
``jax.random`` call (derive fresh keys instead).  ``fold_in`` derives — it
may be applied repeatedly to one parent key with different data.

RNG001  key reused after ``split`` consumed it
RNG002  key consumed by two sampler calls
RNG003  split-result array used whole *and* aliased via a constant
        subscript — the PR 1 ``keys[-1]`` server-key bug (server reused
        the last client's key).  Disjoint slicing (``keys[:-1]`` +
        ``keys[-1]``) is fine and not flagged.
RNG004  ``jax.random`` call inside a loop with all arguments loop-invariant
        — every iteration derives/draws the identical stream.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Module, dotted_name

SPLITTERS = {"split"}
DERIVERS = {"fold_in", "clone"}
SAMPLERS = {
    "normal", "uniform", "randint", "bernoulli", "categorical", "choice",
    "permutation", "shuffle", "gumbel", "exponential", "truncated_normal",
    "bits", "poisson", "gamma", "beta", "dirichlet", "laplace", "logistic",
    "cauchy", "rademacher", "orthogonal", "ball", "maxwell", "loggamma",
    "binomial", "geometric", "rayleigh", "multivariate_normal", "triangular",
    "chisquare",
}
RANDOM_FNS = SPLITTERS | DERIVERS | SAMPLERS


def _alias_map(tree: ast.Module) -> Dict[str, str]:
    """Local alias -> canonical dotted prefix (jax, jax.random, numpy, ...)."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                root = a.name.split(".")[0]
                out[a.asname or root] = a.name if a.asname else root
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _canonical(dotted: str, aliases: Dict[str, str]) -> str:
    head, _, rest = dotted.partition(".")
    base = aliases.get(head, head)
    return f"{base}.{rest}" if rest else base


def jax_random_fn(call: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    """'split' / 'normal' / ... if this is a jax.random call, else None."""
    dotted = dotted_name(call.func)
    if not dotted:
        return None
    canon = _canonical(dotted, aliases)
    if canon.startswith("jax.random."):
        fn = canon.rsplit(".", 1)[1]
        return fn if fn in RANDOM_FNS or fn in ("PRNGKey", "key") else None
    return None


def _key_expr_name(node: ast.AST) -> Optional[str]:
    """Dotted name of a key argument if it is a plain variable/attribute."""
    return dotted_name(node)


def _target_names(target: ast.AST) -> List[str]:
    if isinstance(target, (ast.Tuple, ast.List)):
        out = []
        for e in target.elts:
            out.extend(_target_names(e))
        return out
    d = dotted_name(target)
    return [d] if d else []


class _Scope:
    """Linear (textual-order) event stream over one function or module."""

    def __init__(self, mod: Module, aliases: Dict[str, str]):
        self.mod = mod
        self.aliases = aliases
        self.findings: List[Finding] = []
        # var -> ("split"|"sampler", line) after consumption
        self.consumed: Dict[str, Tuple[str, int]] = {}
        # split-result arrays: var -> assign line
        self.split_arrays: Dict[str, int] = {}
        self.whole_uses: Dict[str, int] = {}
        self.const_subs: Dict[str, List[int]] = {}

    def run(self, body: List[ast.stmt]) -> List[Finding]:
        for stmt in body:
            self._stmt(stmt)
        self._finish_aliasing()
        return self.findings

    # -- statement walk (uses before assigns, bodies in order) ------------

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # nested scopes handled separately
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            if getattr(stmt, "value", None) is not None:
                self._expr(stmt.value)
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            rhs = getattr(stmt, "value", None)
            for t in targets:
                for name in _target_names(t):
                    self._assign(name)
            self._record_split_assign(targets, rhs)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._expr(stmt.iter)
            for name in _target_names(stmt.target):
                self._assign(name)
            self._loop(stmt, stmt.body)
            for s in stmt.body:
                self._stmt(s)
            for s in stmt.orelse:
                self._stmt(s)
        elif isinstance(stmt, ast.While):
            self._expr(stmt.test)
            self._loop(stmt, stmt.body)
            for s in stmt.body:
                self._stmt(s)
            for s in stmt.orelse:
                self._stmt(s)
        elif isinstance(stmt, ast.If):
            self._expr(stmt.test)
            # a branch that terminates (return/raise/break/continue) is
            # exclusive with the fall-through path: consumption inside it
            # cannot alias later uses (`if fast_path: return f(key)` then
            # `g(key)` is two exclusive draws, not a reuse)
            for branch in (stmt.body, stmt.orelse):
                snapshot = dict(self.consumed)
                for s in branch:
                    self._stmt(s)
                if branch and isinstance(
                        branch[-1], (ast.Return, ast.Raise, ast.Break,
                                     ast.Continue)):
                    self.consumed = snapshot
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._expr(item.context_expr)
                if item.optional_vars is not None:
                    for name in _target_names(item.optional_vars):
                        self._assign(name)
            for s in stmt.body:
                self._stmt(s)
        elif isinstance(stmt, ast.Try):
            for s in stmt.body + stmt.orelse + stmt.finalbody:
                self._stmt(s)
            for h in stmt.handlers:
                for s in h.body:
                    self._stmt(s)
        elif isinstance(stmt, (ast.Expr, ast.Return)):
            if stmt.value is not None:
                self._expr(stmt.value)
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._expr(child)

    def _assign(self, name: str) -> None:
        self.consumed.pop(name, None)
        self.split_arrays.pop(name, None)
        self.whole_uses.pop(name, None)
        self.const_subs.pop(name, None)

    def _record_split_assign(self, targets, rhs) -> None:
        if not isinstance(rhs, ast.Call):
            return
        if jax_random_fn(rhs, self.aliases) not in SPLITTERS:
            return
        # single-Name target => the result stays an array of keys
        if len(targets) == 1 and isinstance(targets[0], ast.Name):
            self.split_arrays[targets[0].id] = rhs.lineno

    # -- expression walk --------------------------------------------------

    def _expr(self, node: ast.expr) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._call(sub)
            elif isinstance(sub, ast.Subscript):
                base = dotted_name(sub.value)
                if base and base in self.split_arrays:
                    if self._const_index(sub.slice) is not None:
                        self.const_subs.setdefault(base, []).append(sub.lineno)
                    # slices (keys[:-1]) are disjoint use: neither whole
                    # nor aliasing, so they don't count either way
            elif isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
                if sub.id in self.split_arrays and not self._is_subscript_base(
                        node, sub):
                    self.whole_uses.setdefault(sub.id, sub.lineno)

    @staticmethod
    def _const_index(sl: ast.AST) -> Optional[int]:
        if isinstance(sl, ast.Constant) and isinstance(sl.value, int):
            return sl.value
        if isinstance(sl, ast.UnaryOp) and isinstance(sl.op, ast.USub) \
                and isinstance(sl.operand, ast.Constant) \
                and isinstance(sl.operand.value, int):
            return -sl.operand.value
        return None

    @staticmethod
    def _is_subscript_base(root: ast.expr, name: ast.Name) -> bool:
        for sub in ast.walk(root):
            if isinstance(sub, ast.Subscript) and sub.value is name:
                return True
        return False

    def _call(self, call: ast.Call) -> None:
        fn = jax_random_fn(call, self.aliases)
        if fn is None or fn in ("PRNGKey", "key") or not call.args:
            return
        key = _key_expr_name(call.args[0])
        if key is None:
            return
        prior = self.consumed.get(key)
        if prior is not None:
            kind, at = prior
            rule = "RNG001" if kind == "split" else "RNG002"
            what = "split" if kind == "split" else "a sampler"
            self.findings.append(Finding(
                rule=rule, path=self.mod.path, line=call.lineno,
                message=(f"PRNG key `{key}` reused by jax.random.{fn} after "
                         f"being consumed by {what} at line {at}"),
                hint="derive fresh keys: `k1, k2 = jax.random.split(key)` or "
                     "`jax.random.fold_in(parent, tag)` with distinct tags"))
            self.consumed.pop(key, None)  # one finding per consumption
            return
        if fn in SPLITTERS:
            self.consumed[key] = ("split", call.lineno)
        elif fn in SAMPLERS:
            self.consumed[key] = ("sampler", call.lineno)

    def _finish_aliasing(self) -> None:
        for name, sub_lines in self.const_subs.items():
            whole = self.whole_uses.get(name)
            if whole is None:
                continue
            for line in sub_lines:
                self.findings.append(Finding(
                    rule="RNG003", path=self.mod.path, line=line,
                    message=(f"key array `{name}` from jax.random.split is "
                             f"used whole (line {whole}) and aliased via a "
                             "constant subscript — a consumer of the whole "
                             "array shares this key (the PR 1 `keys[-1]` "
                             "server-key bug)"),
                    hint="split one extra key and use disjoint slices: "
                         "`keys[:-1]` for the cohort, `keys[-1]` for the "
                         "server — never the whole array plus an element"))

    # -- RNG004: loop-invariant draw --------------------------------------

    def _loop(self, loop: ast.stmt, body: List[ast.stmt]) -> None:
        assigned: Set[str] = set()
        if isinstance(loop, (ast.For, ast.AsyncFor)):
            assigned.update(_target_names(loop.target))
        for stmt in body:
            for sub in ast.walk(stmt):
                if isinstance(sub, (ast.Assign,)):
                    for t in sub.targets:
                        assigned.update(_target_names(t))
                elif isinstance(sub, (ast.AugAssign, ast.AnnAssign,
                                      ast.For, ast.AsyncFor)):
                    assigned.update(_target_names(sub.target))
                elif isinstance(sub, ast.withitem) and sub.optional_vars:
                    assigned.update(_target_names(sub.optional_vars))
        for stmt in body:
            for sub in ast.walk(stmt):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Lambda)):
                    break
                if not isinstance(sub, ast.Call):
                    continue
                if self._innermost_loop_of(sub, body) is not loop \
                        and not self._is_direct(sub, stmt, loop):
                    continue
                fn = jax_random_fn(sub, self.aliases)
                if fn is None or fn in ("PRNGKey", "key"):
                    continue
                refs = self._referenced(sub)
                if refs and not (refs & assigned):
                    self.findings.append(Finding(
                        rule="RNG004", path=self.mod.path, line=sub.lineno,
                        message=(f"jax.random.{fn} inside a loop with "
                                 "loop-invariant arguments — every iteration "
                                 "derives the identical PRNG stream"),
                        hint="mix the loop variable in: "
                             "`jax.random.fold_in(key, i)`"))

    def _innermost_loop_of(self, call: ast.Call,
                           body: List[ast.stmt]):
        # nearest For/While strictly containing the call inside this body
        best = None
        for stmt in body:
            for sub in ast.walk(stmt):
                if isinstance(sub, (ast.For, ast.AsyncFor, ast.While)):
                    if any(s is call for s in ast.walk(sub)):
                        best = sub  # deeper matches overwrite; walk order is
                        # outer-first so the last match is innermost
        return best

    def _is_direct(self, call: ast.Call, stmt: ast.stmt,
                   loop: ast.stmt) -> bool:
        # call sits in the loop body with no intervening inner loop
        return self._innermost_loop_of(call, [stmt]) is None

    @staticmethod
    def _referenced(call: ast.Call) -> Set[str]:
        refs: Set[str] = set()
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            for sub in ast.walk(arg):
                d = dotted_name(sub)
                if d:
                    refs.add(d)
                    refs.add(d.split(".")[0])
        return refs


def check(mod: Module) -> List[Finding]:
    aliases = _alias_map(mod.tree)
    findings: List[Finding] = []
    scopes: List[List[ast.stmt]] = [mod.tree.body]
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scopes.append(node.body)
    for body in scopes:
        findings.extend(_Scope(mod, aliases).run(body))
    return findings
