"""Async FL service tests: traffic-model determinism, the staleness-weight
oracle, the sync-degenerate bit-identity contract against FLSimulation
(weights + ledger, perfect wire AND chaos wire), and quarantine/fault
interplay under a stochastic arrival stream."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.faults import FaultPlan
from repro.fl.server import FLServer
from repro.fl.service import (Arrival, BufferedAggregator, DegenerateTraffic,
                              DiurnalTraffic, FLService, PoissonTraffic,
                              staleness_weight)
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn


@pytest.fixture(scope="module")
def setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(400, image_size=cfg.image_size, seed=0)
    test = SyntheticImageDataset(100, image_size=cfg.image_size, seed=1)
    clients = partition_k_shards(train, 4, k_classes=2,
                                 samples_per_client=40)
    yield model, clients, test
    # this module compiles many service/sim pipeline variants; drop the
    # compiled executables so the later end-to-end modules (test_system)
    # don't run on top of this module's accumulated XLA state
    jax.clear_caches()


def _flcfg(**kw):
    base = dict(num_clients=4, clients_per_round=4, local_batch_size=20,
                pca_components=8, clusters_per_class=3, kmeans_iters=4,
                meta_epochs=1, meta_batch_size=10)
    base.update(kw)
    return FLConfig(**base)


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


class _StubServer:
    """Just enough server for traffic-model unit tests."""

    def __init__(self, n, quarantined=()):
        self.n, self.q = n, set(quarantined)

    def eligible_clients(self, num_available):
        return [i for i in range(num_available) if i not in self.q]


class TestTraffic:
    def test_poisson_deterministic_per_seed(self):
        srv = _StubServer(8)
        a = PoissonTraffic(rate=3.0, seed=7, delay_ticks=2)
        b = PoissonTraffic(rate=3.0, seed=7, delay_ticks=2)
        for t in range(12):
            assert a.arrivals(t, srv, 8, None) == b.arrivals(t, srv, 8, None)

    def test_poisson_seed_changes_schedule(self):
        srv = _StubServer(8)
        sched = [PoissonTraffic(rate=3.0, seed=s).arrivals(5, srv, 8, None)
                 for s in range(4)]
        assert len({tuple(s) for s in sched}) > 1

    def test_poisson_tick_keyed_not_call_order(self):
        # drawing tick 9 before tick 2 must not change either schedule
        srv = _StubServer(8)
        tm = PoissonTraffic(rate=3.0, seed=1)
        late_first = (tm.arrivals(9, srv, 8, None),
                      tm.arrivals(2, srv, 8, None))
        early_first = (tm.arrivals(2, srv, 8, None),
                       tm.arrivals(9, srv, 8, None))
        assert late_first == (early_first[1], early_first[0])

    def test_poisson_respects_quarantine(self):
        srv = _StubServer(8, quarantined={0, 3})
        tm = PoissonTraffic(rate=50.0, seed=0)
        ids = {a.client_id for a in tm.arrivals(0, srv, 8, None)}
        assert ids and not (ids & {0, 3})

    def test_diurnal_rate_profile(self):
        tm = DiurnalTraffic(rate=4.0, seed=0, amplitude=1.0, period=24)
        rates = [tm.rate_at(t) for t in range(24)]
        assert max(rates) > 4.0 > min(rates) >= 0.0
        assert tm.rate_at(3) == tm.rate_at(3 + 24)   # periodic

    def test_degenerate_matches_server_sampler(self, setting):
        model, clients, test = setting
        cfg = _flcfg(clients_per_round=3)
        key = jax.random.PRNGKey(42)
        params = model.init(jax.random.PRNGKey(0))
        srv = FLServer(model, params, model.split(params)[1], cfg)
        want = srv.sample_clients(len(clients), key)
        got = DegenerateTraffic().arrivals(0, srv, len(clients), key)
        assert [a.client_id for a in got] == [int(i) for i in want]
        assert all(a.delay == 0 for a in got)


class TestStalenessWeights:
    def test_weight_oracle(self):
        # hand-computed (1 + s) ** -alpha
        assert staleness_weight(0) == 1.0
        assert staleness_weight(3, alpha=0.5) == pytest.approx(0.5)
        assert staleness_weight(1, alpha=1.0) == pytest.approx(0.5)
        assert staleness_weight(2, alpha=0.0) == 1.0
        with pytest.raises(ValueError):
            staleness_weight(-1)

    def test_flush_weights_vs_hand_oracle(self):
        agg = BufferedAggregator(server=None, buffer_size=4,
                                 staleness_alpha=0.5)
        w = agg._weights([0, 1, 3, 2], np.array([True, True, True, False]))
        assert w == pytest.approx([1.0, 2.0 ** -0.5, 0.5, 0.0])

    def test_all_fresh_flush_takes_sync_path(self):
        # all-zero staleness must return None -> FLServer.aggregate's
        # arrival-mask path, the bit-identity contract
        agg = BufferedAggregator(server=None, buffer_size=3)
        assert agg._weights([0, 0, 0], np.array([True, False, True])) is None


class TestSyncDegenerateBitIdentity:
    """The tentpole contract: buffer_size == cohort, zero staleness,
    degenerate arrivals => the service IS the simulator, byte for byte."""

    ROUNDS = 3

    def _run_pair(self, setting, cfg, plan=None):
        model, clients, test = setting
        sim = FLSimulation(model, clients, test, cfg, seed=0,
                           fault_plan=plan, fault_seed=5,
                           quarantine_after=2, quarantine_cooldown=2)
        sres = sim.run(rounds=self.ROUNDS, eval_every=self.ROUNDS)
        svc = FLService(model, clients, test, cfg, seed=0,
                        traffic=DegenerateTraffic(),
                        buffer_size=cfg.clients_per_round,
                        fault_plan=plan, fault_seed=5,
                        quarantine_after=2, quarantine_cooldown=2)
        vres = svc.run(ticks=self.ROUNDS, eval_every=self.ROUNDS)
        return sim, sres, svc, vres

    def test_perfect_wire_weights_and_ledger(self, setting):
        sim, sres, svc, vres = self._run_pair(setting, _flcfg())
        assert _leaves_equal(sim.server.global_params,
                             svc.server.global_params)
        svc_comm = dict(vres.comm)
        sim_comm = {k: v for k, v in sres.comm.items()
                    if k != "total_samples"}
        assert svc_comm == sim_comm
        assert vres.test_acc == sres.test_acc
        assert vres.fedavg_acc == sres.fedavg_acc
        assert vres.flushes == self.ROUNDS
        assert vres.mean_staleness == 0.0

    @pytest.mark.chaos
    def test_chaos_wire_weights_and_ledger(self, setting):
        # faults compose unchanged: the per-(round, client) fault streams
        # line up tick-for-round, so even the chaos ledger is identical
        cfg = _flcfg(transport_checksum=True)
        plan = FaultPlan(drop_rate=0.25, bitflip_rate=0.1,
                         truncate_rate=0.05, duplicate_rate=0.1)
        sim, sres, svc, vres = self._run_pair(setting, cfg, plan=plan)
        assert _leaves_equal(sim.server.global_params,
                             svc.server.global_params)
        sim_comm = {k: v for k, v in sres.comm.items()
                    if k != "total_samples"}
        assert dict(vres.comm) == sim_comm
        assert vres.drops == sres.drops
        assert vres.retransmits == sres.retransmits
        assert vres.corruptions_detected == sres.corruptions_detected
        assert vres.quarantined == sres.quarantined


class TestAsyncService:
    @pytest.mark.chaos
    def test_chaos_arrival_stream_deterministic(self, setting):
        """Poisson arrivals + faults + quarantine + small buffer: the full
        async regime, run twice — everything observable must replay."""
        model, clients, test = setting
        cfg = _flcfg(transport_checksum=True)
        plan = FaultPlan(drop_rate=0.3, bitflip_rate=0.1)

        def once():
            svc = FLService(model, clients, test, cfg, seed=0,
                            traffic=PoissonTraffic(rate=2.0, seed=3,
                                                   delay_ticks=2),
                            buffer_size=2, staleness_alpha=0.5,
                            fault_plan=plan, fault_seed=9,
                            quarantine_after=1, quarantine_cooldown=2)
            res = svc.run(ticks=6, eval_every=4, drain=True)
            return svc, res

        s1, r1 = once()
        s2, r2 = once()
        assert _leaves_equal(s1.server.global_params,
                             s2.server.global_params)
        assert r1.comm == r2.comm
        assert r1.test_acc == r2.test_acc
        assert r1.arrivals_per_tick == r2.arrivals_per_tick
        assert r1.flush_staleness == r2.flush_staleness
        # the stream actually exercised the async machinery
        assert sum(r1.arrivals_per_tick) > 0
        assert r1.flushes > 0

    def test_staleness_accrues_with_delays(self, setting):
        """Delayed uploads survive flushes in the queue -> staleness > 0
        somewhere, and the run still completes + evaluates."""
        model, clients, test = setting
        svc = FLService(model, clients, test, _flcfg(), seed=0,
                        traffic=PoissonTraffic(rate=2.0, seed=11,
                                               delay_ticks=3),
                        buffer_size=2)
        res = svc.run(ticks=8, eval_every=100, drain=True)
        assert res.flushes > 0
        assert res.test_acc          # final flush always evaluated
        assert res.mean_staleness >= 0.0
        flat = [s for fl in res.flush_staleness for s in fl]
        assert any(s > 0 for s in flat)

    def test_quarantined_client_leaves_arrival_pool(self, setting):
        """A client that keeps crashing gets quarantined and stops
        arriving until the cooldown expires."""
        model, clients, test = setting
        # every client crashes before upload -> streaks build immediately
        plan = FaultPlan(drop_rate=1.0)
        svc = FLService(model, clients, test,
                        _flcfg(transport_checksum=True), seed=0,
                        traffic=PoissonTraffic(rate=3.0, seed=2),
                        buffer_size=2, fault_plan=plan, fault_seed=1,
                        quarantine_after=1, quarantine_cooldown=3)
        res = svc.run(ticks=5, eval_every=100, drain=True)
        assert max(res.quarantined) > 0
