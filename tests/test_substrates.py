"""Substrate tests: optimizers, schedules, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.data import (BatchIterator, SyntheticImageDataset,
                        SyntheticTokenDataset, partition_dirichlet,
                        partition_k_shards)
from repro.optim import (adamw, apply_l2, clip_by_global_norm, constant,
                         cosine_decay, global_norm, sgd, step_decay,
                         warmup_cosine)


class TestOptim:
    def _quad(self):
        params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.0)}
        loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
        return params, loss

    def test_sgd_converges_quadratic(self):
        p, loss = self._quad()
        opt = sgd(0.1)
        s = opt.init(p)
        for _ in range(100):
            g = jax.grad(loss)(p)
            p, s = opt.apply(g, s, p)
        assert float(loss(p)) < 1e-6

    def test_sgd_momentum_faster_than_plain(self):
        p0, loss = self._quad()
        def run(opt, n=15):
            p = p0
            s = opt.init(p)
            for _ in range(n):
                p, s = opt.apply(jax.grad(loss)(p), s, p)
            return float(loss(p))
        assert run(sgd(0.05, momentum=0.9)) < run(sgd(0.05))

    def test_adamw_converges(self):
        p, loss = self._quad()
        opt = adamw(0.1)
        s = opt.init(p)
        for _ in range(200):
            p, s = opt.apply(jax.grad(loss)(p), s, p)
        assert float(loss(p)) < 1e-4

    def test_weight_decay_shrinks(self):
        p = {"w": jnp.ones(4)}
        opt = sgd(0.1, weight_decay=0.5)
        s = opt.init(p)
        g = {"w": jnp.zeros(4)}
        p, _ = opt.apply(g, s, p)
        assert float(p["w"][0]) == pytest.approx(0.95)

    def test_l2_penalty_value(self):
        p = {"w": jnp.ones(4)}
        assert float(apply_l2(jnp.array(1.0), p, 0.001)) == pytest.approx(1.004)

    def test_clip_global_norm(self):
        g = {"a": jnp.full(4, 10.0)}
        c = clip_by_global_norm(g, 1.0)
        assert float(global_norm(c)) == pytest.approx(1.0, rel=1e-5)

    def test_schedules(self):
        assert float(constant(0.1)(100)) == pytest.approx(0.1)
        cd = cosine_decay(1.0, 100)
        assert float(cd(0)) == pytest.approx(1.0)
        assert float(cd(100)) == pytest.approx(0.0, abs=1e-6)
        wc = warmup_cosine(1.0, 10, 110)
        assert float(wc(5)) == pytest.approx(0.5)
        sd = step_decay(1.0, [10, 20], 0.1)
        assert float(sd(15)) == pytest.approx(0.1)
        assert float(sd(25)) == pytest.approx(0.01)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"layers": [{"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
                           {"w": np.ones((4,), np.float32)}],
                "step": np.int32(7)}
        save_checkpoint(str(tmp_path), 3, tree, {"note": "x"})
        got, meta = restore_checkpoint(str(tmp_path), tree)
        assert meta["step"] == 3 and meta["note"] == "x"
        np.testing.assert_array_equal(got["layers"][0]["w"],
                                      tree["layers"][0]["w"])

    def test_manager_keeps_last_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
        tree = {"w": np.zeros(3, np.float32)}
        for i in range(5):
            mgr.save(i, tree)
        files = sorted(os.listdir(tmp_path))
        assert len(files) == 2 and mgr.latest == 4

    def test_shape_mismatch_raises(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"w": np.zeros(3, np.float32)})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), {"w": np.zeros(4, np.float32)})

    def test_jax_arrays_roundtrip(self, tmp_path):
        tree = {"w": jnp.arange(4, dtype=jnp.bfloat16)}
        save_checkpoint(str(tmp_path), 1, tree)
        got, _ = restore_checkpoint(str(tmp_path), tree)
        assert got["w"].dtype == jnp.bfloat16


class TestData:
    def test_k_shards_matches_paper_setup(self):
        """§4.1: 20 clients, 2500 images each, 2 classes per client."""
        ds = SyntheticImageDataset(60_000, image_size=8, seed=0)
        clients = partition_k_shards(ds, 20, k_classes=2,
                                     samples_per_client=2500)
        assert len(clients) == 20
        for c in clients:
            assert len(c.data) == 2500
            assert len(np.unique(c.data.y)) <= 2

    def test_dirichlet_partitions_everything_once(self):
        ds = SyntheticImageDataset(2000, image_size=8, seed=0)
        clients = partition_dirichlet(ds, 10, alpha=0.5, seed=0)
        total = sum(len(c.data) for c in clients)
        assert total == 2000

    def test_image_dataset_has_cluster_structure(self):
        """Within-class K-means must beat random grouping (selection needs
        real modes to find)."""
        ds = SyntheticImageDataset(600, image_size=16, modes_per_class=3,
                                   num_classes=4, seed=0)
        x = ds.x[ds.y == 0].reshape(np.sum(ds.y == 0), -1)
        from repro.core.selection import kmeans
        km = kmeans(jnp.asarray(x), 3, jax.random.PRNGKey(0), iters=20)
        inertia = float(km.distances.mean())
        var = float(((x - x.mean(0)) ** 2).sum(-1).mean())
        assert inertia < 0.9 * var   # clusters explain structure

    def test_token_dataset_shapes(self):
        ds = SyntheticTokenDataset(100, seq_len=32, vocab_size=64)
        assert ds.x.shape == (100, 32) and ds.x.max() < 64

    def test_batch_iterator_epochs(self):
        ds = SyntheticImageDataset(55, image_size=8, seed=0)
        it = BatchIterator(ds, 10, seed=0)
        seen = [next(it) for _ in range(7)]     # crosses an epoch boundary
        assert all(b[0].shape == (10, 8, 8, 3) for b in seen)
        assert it.epoch >= 1

    def test_small_client_upsampled(self):
        ds = SyntheticImageDataset(5, image_size=8, seed=0)
        it = BatchIterator(ds, 16, seed=0)
        x, y = next(it)
        assert x.shape[0] == 16


@settings(max_examples=20, deadline=None)
@given(n=st.integers(20, 200), nc=st.integers(2, 10), k=st.integers(1, 4),
       seed=st.integers(0, 99))
def test_property_partition_class_budget(n, nc, k, seed):
    k = min(k, 10)
    ds = SyntheticImageDataset(n, image_size=8, num_classes=10, seed=seed)
    clients = partition_k_shards(ds, nc, k_classes=k, seed=seed)
    for c in clients:
        assert len(np.unique(c.data.y)) <= k
