"""repro.fl.transport: codec round-trip property tests, the Pallas
quantize kernel vs its oracle (masked rows, non-aligned shapes, vmap), and
ledger byte-exactness — every CommLedger entry of a full simulated round
equals the exact byte length of the encoded messages, on both the
sequential and the distributed engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl import transport as T
from repro.fl.comms import CommLedger
from repro.fl.simulation import FLSimulation
from repro.kernels import ops, ref
from repro.models.wrn import make_split_wrn

KEY = jax.random.PRNGKey(3)


def _triple(rng, ck=30, shape=(4, 4, 2), frac_valid=0.6):
    acts = jnp.asarray((rng.normal(size=(ck,) + shape) * 5).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, ck), jnp.int32)
    valid = jnp.asarray(rng.random(ck) < frac_valid)
    return acts, labels, valid


# ------------------------------------------------------------------ codecs
class TestCodecRoundTrip:
    def test_raw_f32_identity(self):
        rng = np.random.default_rng(0)
        acts, labels, valid = _triple(rng)
        a, l, v = T.SelectedKnowledge.decode(
            T.SelectedKnowledge(acts, labels, valid,
                                T.get_codec("raw_f32")).encode())
        m = np.asarray(valid)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(acts)[m])
        np.testing.assert_array_equal(np.asarray(l), np.asarray(labels)[m])
        assert v.dtype == bool and bool(v.all()) and v.shape == (m.sum(),)

    def test_f16_roundtrip_within_half_precision(self):
        rng = np.random.default_rng(1)
        acts, labels, valid = _triple(rng)
        a, l, _ = T.SelectedKnowledge.decode(
            T.SelectedKnowledge(acts, labels, valid,
                                T.get_codec("f16")).encode())
        want = np.asarray(acts)[np.asarray(valid)]
        # exactly the f16 cast — the codec loses nothing beyond the dtype
        np.testing.assert_array_equal(
            np.asarray(a), want.astype(np.float16).astype(np.float32))
        np.testing.assert_array_equal(np.asarray(l),
                                      np.asarray(labels)[np.asarray(valid)])

    @settings(max_examples=15, deadline=None)
    @given(ck=st.integers(1, 64), d=st.integers(1, 64),
           seed=st.integers(0, 999))
    def test_int8_error_bound_property(self, ck, d, seed):
        """|decode(encode(x)) - x| <= scale/2 (+ a few ulp) on every valid
        element, for any shape/mask."""
        rng = np.random.default_rng(seed)
        acts = jnp.asarray((rng.normal(size=(ck, d)) * 10).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 10, ck), jnp.int32)
        valid = jnp.asarray(rng.random(ck) < 0.7)
        codec = T.get_codec("int8")
        wire = T.SelectedKnowledge(acts, labels, valid, codec).encode()
        a, _, _ = T.SelectedKnowledge.decode(wire)
        m = np.asarray(valid)
        if not m.any():
            assert a.shape[0] == 0
            return
        _, _, scale = ref.quantize_affine_ref(acts, valid)
        err = np.abs(np.asarray(a) - np.asarray(acts)[m]).max()
        assert err <= float(scale) * 0.5 * (1 + 1e-4) + 1e-6

    def test_int8_upload_at_least_3_5x_smaller_than_raw(self):
        """The acceptance ratio at selection-like payload shapes."""
        rng = np.random.default_rng(2)
        acts, labels, valid = _triple(rng, ck=100, shape=(16, 16, 16))
        raw = len(T.SelectedKnowledge(acts, labels, valid,
                                      T.get_codec("raw_f32")).encode())
        i8 = len(T.SelectedKnowledge(acts, labels, valid,
                                     T.get_codec("int8")).encode())
        assert raw >= 3.5 * i8, (raw, i8)

    def test_empty_and_all_invalid_payloads(self):
        rng = np.random.default_rng(3)
        acts, labels, _ = _triple(rng)
        for name in ("raw_f32", "f16", "int8"):
            codec = T.get_codec(name)
            wire = T.SelectedKnowledge(acts, labels,
                                       jnp.zeros(30, bool), codec).encode()
            a, l, v = T.SelectedKnowledge.decode(wire)
            assert a.shape == (0, 4, 4, 2) and l.shape == (0,) \
                and v.shape == (0,)
            # an all-invalid frame is framing + bitmap + params only
            assert len(wire) < 64

    def test_weight_messages_roundtrip_native_dtypes(self):
        rng = np.random.default_rng(4)
        tree = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                "moe": {"idx": jnp.asarray(rng.integers(0, 9, (5,)),
                                           jnp.int32),
                        "h": jnp.asarray(rng.normal(size=(2, 3)),
                                         jnp.bfloat16)}}
        for cls in (T.WeightBroadcast, T.UpperUpdate):
            wire = cls(tree).encode()
            back = T.unflatten_like(tree, cls.decode(wire))
            for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
                assert x.dtype == y.dtype
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            # itemsize-true: bf16/int leaves are NOT billed as f32
            payload = sum(np.asarray(x).nbytes
                          for x in jax.tree.leaves(tree))
            assert payload <= len(wire) <= payload + 64

    def test_pytree_frame_nbytes_equals_encoded_length(self):
        # the ledger charges weight frames by this arithmetic size instead
        # of serializing the model — it must track len(encode()) exactly
        rng = np.random.default_rng(5)
        tree = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16),
                "step": jnp.asarray(7, jnp.int32),
                "idx": jnp.asarray(rng.integers(0, 9, (2, 3, 4)), jnp.int64)}
        assert (T.pytree_frame_nbytes(tree)
                == len(T.WeightBroadcast(tree).encode())
                == len(T.UpperUpdate(tree).encode()))
        with pytest.raises(ValueError):       # same contract as encode()
            T.pytree_frame_nbytes({"c": np.zeros(2, np.complex64)})

    def test_frame_validation(self):
        wire = T.WeightBroadcast({"a": jnp.zeros((2,))}).encode()
        with pytest.raises(ValueError):
            T.WeightBroadcast.decode(b"XXXX" + wire[4:])
        with pytest.raises(ValueError):
            T.SelectedKnowledge.decode(wire)     # wrong message type
        with pytest.raises(ValueError):
            T.get_codec("gzip")


# ---------------------------------------------------------- quantize kernel
class TestQuantizeKernel:
    """Acceptance: the Pallas quantize kernel matches ref.py bit-for-bit in
    interpret mode — masked rows, non-aligned shapes, vmap."""

    @pytest.mark.parametrize("n,d,masked", [
        (256, 128, 0),       # aligned, unmasked
        (256, 128, 60),      # aligned, masked rows
        (300, 37, 25),       # non-aligned N and D
        (100, 200, 100),     # every row masked
        (64, 1, 3),          # single column
        (513, 129, 7),       # non-aligned, multi-block
    ])
    def test_kernel_matches_oracle_bitwise(self, n, d, masked):
        rng = np.random.default_rng(n + d + masked)
        x = jnp.asarray((rng.normal(size=(n, d)) * 10).astype(np.float32))
        mask = np.ones(n, bool)
        if masked:
            mask[rng.choice(n, masked, replace=False)] = False
        mask = jnp.asarray(mask)
        q, xmin, scale = ops.quantize_affine(x, mask)
        rq, rxmin, rscale = ref.quantize_affine_ref(x, mask)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(rq))
        assert float(xmin) == float(rxmin)
        assert float(scale) == float(rscale)
        # masked rows quantize to the deterministic floor level
        if masked:
            assert (np.asarray(q)[~np.asarray(mask)] == -128).all()

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(64, 400), d=st.integers(1, 96),
           seed=st.integers(0, 999))
    def test_kernel_property(self, n, d, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray((rng.normal(size=(n, d)) * 10).astype(np.float32))
        mask = jnp.asarray(rng.random(n) > 0.3)
        q, xmin, scale = ops.quantize_affine(x, mask)
        rq, rxmin, rscale = ref.quantize_affine_ref(x, mask)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(rq))
        assert (float(xmin), float(scale)) == (float(rxmin), float(rscale))

    def test_kernel_vmap_clients(self):
        """vmapped (stacked-cohort) quantize == per-client calls — the
        distributed encoder's bit-identity to the sequential one."""
        rng = np.random.default_rng(7)
        xb = jnp.asarray((rng.normal(size=(4, 128, 48)) * 3)
                         .astype(np.float32))
        mb = jnp.asarray(rng.random((4, 128)) > 0.4)
        qb, xminb, scaleb = jax.vmap(ops.quantize_affine)(xb, mb)
        for i in range(4):
            qi, xi, si = ops.quantize_affine(xb[i], mb[i])
            np.testing.assert_array_equal(np.asarray(qb[i]), np.asarray(qi))
            assert float(xminb[i]) == float(xi)
            assert float(scaleb[i]) == float(si)

    def test_constant_tensor_exact(self):
        x = jnp.full((128, 16), -2.25)
        q, xmin, scale = ops.quantize_affine(x, jnp.ones(128, bool))
        assert float(xmin) == -2.25 and float(scale) == 1.0
        back = ref.dequantize_affine_ref(q, xmin, scale)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# ------------------------------------------------------- ledger exactness
@pytest.fixture(scope="module")
def sim_setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(400, image_size=cfg.image_size, seed=0)
    test = SyntheticImageDataset(80, image_size=cfg.image_size, seed=1)
    clients = partition_k_shards(train, 4, k_classes=2,
                                 samples_per_client=40)
    return model, clients, test


def _flcfg(**kw):
    base = dict(num_clients=4, clients_per_round=4, local_batch_size=20,
                pca_components=8, clusters_per_class=3, kmeans_iters=4,
                meta_epochs=1, meta_batch_size=10)
    base.update(kw)
    return FLConfig(**base)


def _expected_round_bytes(model, sim, cfg):
    """Replay round 0's sampling/keys on a fresh same-seed simulation and
    encode every frame the round exchanges — the knowledge frames from the
    PRE-transport selection triples (``select_for_clients``), the weight
    frames from the updated client params — independently of the channel's
    own charging path. -> expected (up, down) ledger dicts."""
    from repro.core.rounds import run_cohort, select_for_clients
    codec = T.knowledge_codec(cfg)
    down = len(T.WeightBroadcast(sim.server.global_params).encode())
    _, k_round, k_sample = jax.random.split(sim.key, 3)
    idx = sim.server.sample_clients(len(sim.clients), k_sample)
    keys = jax.random.split(k_round, len(idx))
    cohort = [sim.clients[int(i)].client for i in idx]
    pre = select_for_clients(model, sim.server.global_params, cohort, cfg,
                             keys, sim.num_classes)
    assert pre is not None
    up_m = sum(len(T.SelectedKnowledge(a, l, v, codec).encode())
               for _, _, (a, l, v), _ in pre)
    scratch = CommLedger()
    cparams, _, _ = run_cohort(model, sim.server.global_params, cohort,
                               cfg, keys, scratch, sim.num_classes)
    up_w = sum(len(T.UpperUpdate(p).encode()) for p in cparams)
    return ({"metadata": up_m, "weights": up_w},
            {"weights": down * len(cohort)})


class TestLedgerByteExactness:
    @pytest.mark.parametrize("codec", ["raw_f32", "f16", "int8"])
    def test_full_round_ledger_equals_encoded_bytes_sequential(
            self, sim_setting, codec):
        model, clients, test = sim_setting
        cfg = _flcfg(transport_codec=codec)
        fresh = FLSimulation(model, clients, test, cfg, seed=0)
        up, down = _expected_round_bytes(model, fresh, cfg)
        res = FLSimulation(model, clients, test, cfg, seed=0).run(rounds=1)
        assert res.comm["up"] == up
        assert res.comm["down"] == down

    @pytest.mark.parametrize("codec", ["raw_f32", "int8"])
    def test_full_round_ledger_equals_encoded_bytes_distributed(
            self, sim_setting, codec):
        """The acceptance criterion's distributed half: a full FLSimulation
        on the stacked engine charges exactly the encoded frame bytes —
        and therefore matches the sequential path's ledger entry for
        entry."""
        model, clients, test = sim_setting
        cfg = _flcfg(transport_codec=codec, distributed_selection=True)
        fresh = FLSimulation(model, clients, test, cfg, seed=0)
        up, down = _expected_round_bytes(model, fresh, cfg)
        res = FLSimulation(model, clients, test, cfg, seed=0).run(rounds=1)
        assert res.comm["up"] == up
        assert res.comm["down"] == down
        seq = FLSimulation(
            model, clients, test,
            dataclasses.replace(cfg, distributed_selection=False),
            seed=0).run(rounds=1)
        assert res.comm == seq.comm

    def test_int8_simulation_completes_and_learns_signal(self, sim_setting):
        """transport_codec='int8' end to end: the decoded (lossy) metadata
        feeds MetaTraining and the simulation still runs to completion with
        finite losses/accuracies and a populated byte-true ledger."""
        model, clients, test = sim_setting
        res = FLSimulation(model, clients, test,
                           _flcfg(transport_codec="int8"),
                           seed=0).run(rounds=2)
        assert np.isfinite(res.client_loss).all()
        assert np.isfinite(res.test_acc).all()
        assert res.metadata_counts[-1] > 0
        assert res.comm["up"]["metadata"] > 0
