"""Distributed-step tests on a subprocess smoke mesh (4-8 host devices):
the stacked-clients FedAvg train step EXECUTES and matches the sequential
simulator's math; the pod-scale selection engine (repro.core.distributed)
shards a round over the mesh bit-identically; dryrun lowers for
representative pairs.

These spawn subprocesses because jax pins the host device count at first
init (the main pytest process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_step_executes_and_fedavg_synchronizes():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, TrainConfig
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_smoke_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import make_train_step

cfg = get_config("llama3.2-1b").reduced()
tcfg = TrainConfig(local_steps=2, microbatch=2, split_fl=True, meta_clusters=2,
                   pca_components=4, remat=False, dtype="float32")
mesh = make_smoke_mesh()
step, lm = make_train_step(cfg, tcfg)
shape = ShapeConfig("t", 16, 4, "train")
specs = input_specs(cfg, shape, mesh, tcfg, lm=lm)
g = specs["g"]
params0 = lm.init(jax.random.PRNGKey(0))
cp = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (g,)+x.shape), params0)
with mesh:
    jit_step = jax.jit(step)
    tok = jax.random.randint(jax.random.PRNGKey(1),
                             specs["batch"]["tokens"].shape, 0, cfg.vocab_size)
    new_cp, _, metrics = jit_step(cp, (), {"tokens": tok}, jax.random.PRNGKey(2))
loss = float(metrics["loss"])
assert np.isfinite(loss), loss
# FedAvg redistribution: all cohorts leave with identical weights
leaf = np.asarray(jax.tree.leaves(new_cp)[0])
for i in range(1, leaf.shape[0]):
    np.testing.assert_allclose(leaf[0], leaf[i], rtol=1e-5, atol=1e-6)
# weights actually changed
old = np.asarray(jax.tree.leaves(cp)[0])
assert not np.allclose(leaf, old)
print("OK", loss, float(metrics.get("selected", -1)))
"""
    r = run_py(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_fedavg_step_matches_sequential_math():
    """G cohorts, local_steps=1, no split-fl: the lowered step must equal
    plain per-cohort SGD then mean (computed sequentially in numpy)."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, TrainConfig
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_smoke_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import make_train_step
from repro.optim import sgd

cfg = get_config("qwen2-0.5b").reduced()
tcfg = TrainConfig(local_steps=1, microbatch=4, split_fl=False,
                   remat=False, dtype="float32", lr=0.1)
mesh = make_smoke_mesh()
step, lm = make_train_step(cfg, tcfg)
shape = ShapeConfig("t", 16, 8, "train")
specs = input_specs(cfg, shape, mesh, tcfg, lm=lm)
g = specs["g"]
params0 = lm.init(jax.random.PRNGKey(0))
cp = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (g,)+x.shape), params0)
tok = jax.random.randint(jax.random.PRNGKey(1),
                         specs["batch"]["tokens"].shape, 0, cfg.vocab_size)
with mesh:
    new_cp, _, m = jax.jit(step)(cp, (), {"tokens": tok}, jax.random.PRNGKey(2))

# sequential reference
opt = sgd(0.1)
client_ps = []
for c in range(g):
    p = params0
    grads = jax.grad(lambda p_: lm.loss(p_, {"tokens": tok[c,0,0]}))(p)
    # grad accumulation over micro steps
    for mi in range(1, tok.shape[2]):
        g2 = jax.grad(lambda p_: lm.loss(p_, {"tokens": tok[c,0,mi]}))(p)
        grads = jax.tree.map(jnp.add, grads, g2)
    grads = jax.tree.map(lambda x: x / tok.shape[2], grads)
    p, _ = opt.apply(grads, opt.init(p), p)
    client_ps.append(p)
avg = jax.tree.map(lambda *xs: sum(xs)/len(xs), *client_ps)
got = jax.tree.map(lambda x: np.asarray(x[0]), new_cp)
ref_l = jax.tree.leaves(avg); got_l = jax.tree.leaves(got)
err = max(float(np.abs(np.asarray(a)-np.asarray(b)).max()) for a,b in zip(ref_l, got_l))
assert err < 2e-4, err
print("OK maxerr", err)
"""
    r = run_py(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("gemma3-4b", "long_500k"),
    ("qwen3-moe-30b-a3b", "decode_32k"),
    ("rwkv6-3b", "prefill_32k"),
])
def test_dryrun_smoke_subprocess(arch, shape):
    env = dict(os.environ, _REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", arch, "--shape", shape, "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, (r.stdout + r.stderr)[-2500:]
    assert "[ok]" in r.stdout


@pytest.mark.slow
def test_dryrun_multipod_smoke():
    env = dict(os.environ, _REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke", "--multipod",
         "--arch", "llama3.2-1b", "--shape", "train_4k",
         "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, (r.stdout + r.stderr)[-2500:]
    assert "[ok]" in r.stdout


@pytest.mark.slow
def test_sharded_selection_round_matches_sequential_simulator():
    """The pod engine on a smoke mesh of 8 host devices: shard_map'd
    Extract&Selection + sharded stacked LocalUpdate over the client axis
    must reproduce the sequential per-client simulator bit-for-bit — with
    and without chunked streaming on top."""
    code = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import FLConfig, get_wrn_config
from repro.core.rounds import run_round
from repro.core.distributed import selection_mesh
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.models.wrn import make_split_wrn

assert len(jax.devices()) == 8
KEY = jax.random.PRNGKey(0)
cfg = get_wrn_config().reduced()
model = make_split_wrn(cfg)
params = model.init(KEY)
ds = SyntheticImageDataset(500, image_size=cfg.image_size, seed=0)
clients = partition_k_shards(ds, 6, k_classes=2, samples_per_client=40)
flcfg = FLConfig(num_clients=6, clients_per_round=6, local_batch_size=20,
                 pca_components=8, clusters_per_class=3, kmeans_iters=4,
                 meta_epochs=1, meta_batch_size=10, local_epochs=2)
_, upper0 = model.split(params)
mesh = selection_mesh()          # (8,) 'data' mesh; 6 clients pad to 8

def check(a, b):
    assert a.metadata_count == b.metadata_count
    assert a.client_losses == b.client_losses
    for x, y in zip(jax.tree.leaves(a.global_params),
                    jax.tree.leaves(b.global_params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(a.composed_params),
                    jax.tree.leaves(b.composed_params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))

seq = run_round(model, params, upper0, clients,
                dataclasses.replace(flcfg, batched_selection=False), KEY)
sharded = run_round(model, params, upper0, clients,
                    dataclasses.replace(flcfg, distributed_selection=True),
                    KEY, mesh=mesh)
check(sharded, seq)
# chunked streaming on top of the sharded path (chunks pad per-chunk)
sharded_chunked = run_round(
    model, params, upper0, clients,
    dataclasses.replace(flcfg, distributed_selection=True,
                        selection_chunk_size=4), KEY, mesh=mesh)
check(sharded_chunked, seq)
print("OK sharded==sequential")
"""
    r = run_py(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_hlo_parser_units():
    from repro.launch.hlo_analysis import parse_hlo
    hlo = '''
HloModule test, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %a = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %b = f32[256,64]{1,0} constant(0)
  %d = f32[128,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,64]{1,0} all-reduce(%d), to_apply=%add
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %a)
}

ENTRY %main () -> f32[] {
  %init = (s32[], f32[128,256]) tuple(...)
  %w = (s32[], f32[128,256]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %r = f32[] constant(0)
}
'''
    c = parse_hlo(hlo)
    # dot: 2*128*64*256 = 4.19e6 per trip, 10 trips
    assert abs(c.flops - 2 * 128 * 64 * 256 * 10) / c.flops < 1e-6
    assert c.coll_count.get("all-reduce") == 10
    assert c.coll_bytes["all-reduce"] == 128 * 64 * 4 * 10
