"""End-to-end behaviour tests for the paper's system: a multi-round FL
simulation on synthetic data must (a) learn, (b) transfer client data
characteristics through <5% selected metadata, (c) show the paper's
qualitative orderings (selection < full metadata; more clusters helps)."""
import jax
import numpy as np
import pytest

from repro.configs import FLConfig, get_wrn_config
from repro.core.compose import evaluate
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn


@pytest.fixture(scope="module")
def setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(3000, image_size=cfg.image_size,
                                  num_classes=10, modes_per_class=3,
                                  noise=0.25, seed=0)
    test = SyntheticImageDataset(600, image_size=cfg.image_size,
                                 num_classes=10, modes_per_class=3,
                                 noise=0.25, seed=1)
    clients = partition_k_shards(train, 4, k_classes=3,
                                 samples_per_client=300, seed=0)
    return cfg, model, clients, test


@pytest.mark.slow
def test_simulation_learns_and_selects(setting):
    cfg, model, clients, test = setting
    flcfg = FLConfig(num_clients=4, clients_per_round=4, local_epochs=2,
                     local_batch_size=50, local_lr=0.1,
                     pca_components=24, clusters_per_class=4, kmeans_iters=8,
                     meta_epochs=10, meta_batch_size=20, meta_lr=0.05)
    sim = FLSimulation(model, clients, test, flcfg, seed=0)
    res = sim.run(rounds=5, eval_every=5)
    # learning signals at this 1-core scale (full-scale convergence is
    # examples/paper_repro.py):
    #  * local training works: client loss decreases monotonically-ish
    #  * the COMPOSED model (the paper's contribution) is above chance —
    #    notably it beats the plain FedAvg average at this round count, whose
    #    non-IID client drift is the paper's motivating pathology
    assert res.client_loss[-1] < 0.7 * res.client_loss[0], res.client_loss
    assert res.test_acc[-1] > 0.10, res.test_acc
    assert np.isfinite(res.fedavg_acc[-1])
    # the paper's headline: metadata is a small fraction of local data
    frac = res.metadata_counts[-1] / res.comm["total_samples"]
    assert frac < 0.05, frac
    # comm ledger populated on both directions
    assert res.comm["up"]["metadata"] > 0
    assert res.comm["up"]["weights"] > 0
    assert res.comm["down"]["weights"] > 0


@pytest.mark.slow
def test_metadata_bytes_scale_with_clusters(setting):
    """More clusters -> more representative maps -> more upload bytes
    (Table 4's knob, comm-side)."""
    cfg, model, clients, test = setting
    base = dict(num_clients=4, clients_per_round=4, local_epochs=1,
                local_batch_size=50, pca_components=16, kmeans_iters=5,
                meta_epochs=2, meta_batch_size=20)
    sims = {}
    for k in (2, 6):
        flcfg = FLConfig(clusters_per_class=k, **base)
        sim = FLSimulation(model, clients, test, flcfg, seed=0)
        res = sim.run(rounds=1)
        sims[k] = (res.metadata_counts[-1], res.comm["up"]["metadata"])
    assert sims[6][0] > sims[2][0]
    assert sims[6][1] > sims[2][1]
