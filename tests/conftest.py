import os
import sys

# Tests see ONE device (never set the 512-device dry-run flag globally);
# dry-run smoke tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


def _install_hypothesis_shim():
    """Minimal stand-in for the slice of the hypothesis API these tests use
    (``@settings``/``@given`` + ``strategies.integers``) so the suite collects
    on machines without the dependency. Property tests still run, as seeded
    random sweeps drawn from the declared strategies."""
    import functools
    import inspect
    import random
    import types

    mod = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")

    class _Integers:
        def __init__(self, min_value, max_value):
            self.min_value, self.max_value = min_value, max_value

        def sample(self, rng):
            return rng.randint(self.min_value, self.max_value)

    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", 10)
                rng = random.Random(0)
                for _ in range(n):
                    draw = {k: s.sample(rng) for k, s in strats.items()}
                    fn(*args, **draw, **kwargs)
            # hide the strategy params from pytest's fixture resolution
            # (real hypothesis does the same via a zero-arg signature)
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    mod.given, mod.settings, mod.strategies = given, settings, strategies
    mod.__version__ = "0.0.0-shim"
    strategies.integers = integers
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
