import os

# Tests see ONE device (never set the 512-device dry-run flag globally);
# dry-run smoke tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
