"""Unit + property tests for the paper's §3.1 selection pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.selection import (kmeans, pca_fit, pca_transform,
                                  representatives, select_metadata,
                                  select_metadata_batched,
                                  select_metadata_reference,
                                  selected_fraction)

KEY = jax.random.PRNGKey(0)


def structured_acts(seed, n=400):
    """Low-rank mode-structured activation maps (decaying spectrum) — the
    regime real split-layer activations live in; same generator the
    selection benchmark validates against."""
    from repro.data import SyntheticActivationMaps
    ds = SyntheticActivationMaps(n, (8, 8, 4), num_classes=4,
                                 modes_per_class=3, rank=48,
                                 spectrum_decay=0.9, seed=seed,
                                 structure_seed=seed)
    return jnp.asarray(ds.x), jnp.asarray(ds.y)


class TestPCA:
    def test_reconstruction_identity_when_full_rank(self):
        x = np.random.default_rng(0).normal(size=(50, 8)).astype(np.float32)
        st_ = pca_fit(jnp.asarray(x), 8)
        z = pca_transform(st_, jnp.asarray(x))
        xr = z @ st_.components + st_.mean
        np.testing.assert_allclose(np.asarray(xr), x, atol=1e-3)

    def test_components_orthonormal(self):
        x = jnp.asarray(np.random.default_rng(1).normal(size=(40, 100)),
                        jnp.float32)
        st_ = pca_fit(x, 10)
        g = np.asarray(st_.components @ st_.components.T)
        np.testing.assert_allclose(g, np.eye(10), atol=1e-3)

    def test_gram_vs_cov_paths_agree(self):
        # n<d triggers the Gram trick; n>d the covariance path
        rng = np.random.default_rng(2)
        base = rng.normal(size=(300, 20)).astype(np.float32)
        st_small = pca_fit(jnp.asarray(base[:15]), 4)     # gram
        st_big = pca_fit(jnp.asarray(base), 4)            # cov
        # both must capture descending variance
        assert np.all(np.diff(np.asarray(st_small.explained)) <= 1e-4)
        assert np.all(np.diff(np.asarray(st_big.explained)) <= 1e-4)

    def test_variance_ordering_dominant_direction(self):
        rng = np.random.default_rng(3)
        x = np.concatenate([rng.normal(0, 10, (200, 1)),
                            rng.normal(0, 0.1, (200, 5))], 1).astype(np.float32)
        st_ = pca_fit(jnp.asarray(x), 2)
        c0 = np.abs(np.asarray(st_.components[0]))
        assert c0[0] > 0.99   # first component = the high-variance axis

    def test_mask_excludes_rows(self):
        x = np.zeros((10, 4), np.float32)
        x[5:] = 1000.0   # garbage rows, masked out
        mask = jnp.asarray([True] * 5 + [False] * 5)
        st_ = pca_fit(jnp.asarray(x), 2, mask=mask)
        assert float(jnp.abs(st_.mean).max()) < 1e-3


class TestKMeans:
    def test_separated_clusters_found(self):
        rng = np.random.default_rng(0)
        centers = np.array([[0, 0], [10, 10], [-10, 10]], np.float32)
        x = np.concatenate([c + rng.normal(0, .3, (50, 2)) for c in centers])
        km = kmeans(jnp.asarray(x, jnp.float32), 3, KEY, iters=20)
        # each true cluster maps to exactly one centroid
        found = np.asarray(km.centroids)
        d = np.linalg.norm(found[:, None] - centers[None], axis=-1).min(0)
        assert d.max() < 1.0
        assert np.asarray(km.cluster_sizes).sum() == 150

    def test_assignment_is_nearest(self):
        x = jnp.asarray(np.random.default_rng(1).normal(size=(64, 5)),
                        jnp.float32)
        km = kmeans(x, 4, KEY, iters=10)
        d = ((np.asarray(x)[:, None] - np.asarray(km.centroids)[None]) ** 2
             ).sum(-1)
        np.testing.assert_array_equal(d.argmin(1), np.asarray(km.assignment))

    def test_mask_keeps_invalid_out_of_centroids(self):
        rng = np.random.default_rng(2)
        x = np.concatenate([rng.normal(0, 1, (30, 3)),
                            np.full((10, 3), 1e4)]).astype(np.float32)
        mask = jnp.asarray([True] * 30 + [False] * 10)
        km = kmeans(jnp.asarray(x), 3, KEY, iters=10, mask=mask)
        assert float(jnp.abs(km.centroids).max()) < 100.0

    def test_representatives_belong_to_their_cluster(self):
        x = jnp.asarray(np.random.default_rng(3).normal(size=(80, 4)),
                        jnp.float32)
        km = kmeans(x, 5, KEY, iters=10)
        reps = representatives(x, km)
        for j, r in enumerate(np.asarray(reps)):
            if np.asarray(km.cluster_sizes)[j] > 0:
                assert int(np.asarray(km.assignment)[r]) == j


class TestRepresentativesEmptyClusters:
    """Contract: an empty cluster yields the index of the valid point
    globally nearest to that cluster's centre (it used to be the argmin of
    an all-BIG column — always row 0, regardless of geometry)."""

    def _check_contract(self, x, km, reps, mask=None):
        xn = np.asarray(x)
        valid = (np.ones(len(xn), bool) if mask is None
                 else np.asarray(mask, bool))
        cents = np.asarray(km.centroids)
        sizes = np.asarray(km.cluster_sizes)
        assign = np.asarray(km.assignment)
        d = ((xn[:, None] - cents[None]) ** 2).sum(-1)
        d[~valid] = np.inf
        for j, r in enumerate(np.asarray(reps)):
            if sizes[j] > 0:
                assert assign[r] == j          # old contract, unchanged
            else:
                assert d[:, j].argmin() == r   # nearest valid point

    def test_empty_cluster_yields_nearest_valid(self):
        # 3 distinct points, 6 clusters -> empty clusters guaranteed
        x = jnp.asarray(np.repeat(np.array([[0., 0.], [10., 0.], [0., 10.]],
                                           np.float32), 4, axis=0))
        km = kmeans(x, 6, KEY, iters=5)
        assert (np.asarray(km.cluster_sizes) == 0).any()
        reps = representatives(x, km)
        assert np.asarray(reps).max() < x.shape[0]
        self._check_contract(x, km, reps)

    def test_empty_cluster_masked_path(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(30, 3)), jnp.float32)
        mask = jnp.asarray([True] * 4 + [False] * 26)   # 4 valid rows, k=6
        km = kmeans(x, 6, KEY, iters=5, mask=mask)
        assert (np.asarray(km.cluster_sizes) == 0).any()
        reps = representatives(x, km, mask=mask)
        self._check_contract(x, km, reps, mask=mask)
        # every representative is a VALID row, not an arbitrary row 0
        for j, r in enumerate(np.asarray(reps)):
            assert bool(mask[r])

    def test_fused_per_class_matches_reference_with_empty_slots(self):
        """A class with fewer points than clusters forces empty slots in
        the masked per-class path; the fused engine's fallback must agree
        with the reference path's ``representatives(mask=...)``."""
        rng = np.random.default_rng(1)
        acts = rng.normal(size=(60, 12)).astype(np.float32)
        labels = np.full(60, 1, np.int64)
        labels[:2] = 0                          # class 0: 2 points, 4 slots
        kw = dict(num_classes=2, clusters_per_class=4, pca_components=6,
                  kmeans_iters=6)
        a = select_metadata(jnp.asarray(acts), jnp.asarray(labels), KEY, **kw)
        b = select_metadata_reference(jnp.asarray(acts), jnp.asarray(labels),
                                      KEY, **kw)
        assert not np.asarray(a.valid).all()    # empty slots really exist
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))
        np.testing.assert_array_equal(np.asarray(a.valid),
                                      np.asarray(b.valid))
        # empty slots of class 0 point at class-0 rows (the admissible set)
        idx = np.asarray(a.indices).reshape(2, 4)
        valid = np.asarray(a.valid).reshape(2, 4)
        for j in range(4):
            if not valid[0, j]:
                assert labels[idx[0, j]] == 0


class TestSelectMetadata:
    def test_paper_shape_contract(self):
        """20 clusters/class x 10 classes -> 200 selected (Table 5 setup)."""
        rng = np.random.default_rng(0)
        acts = rng.normal(size=(500, 6, 6, 4)).astype(np.float32)
        labels = rng.integers(0, 10, 500)
        s = select_metadata(jnp.asarray(acts), jnp.asarray(labels), KEY,
                            num_classes=10, clusters_per_class=20,
                            pca_components=32, kmeans_iters=5)
        assert s.indices.shape == (200,)
        frac = float(selected_fraction(s, 500))
        assert 0 < frac <= 0.41

    def test_selected_indices_have_right_class(self):
        rng = np.random.default_rng(1)
        acts = rng.normal(size=(200, 16)).astype(np.float32)
        labels = rng.integers(0, 4, 200)
        s = select_metadata(jnp.asarray(acts), jnp.asarray(labels), KEY,
                            num_classes=4, clusters_per_class=5,
                            pca_components=8, kmeans_iters=5)
        idx = np.asarray(s.indices).reshape(4, 5)
        valid = np.asarray(s.valid).reshape(4, 5)
        for c in range(4):
            for j in range(5):
                if valid[c, j]:
                    assert labels[idx[c, j]] == c

    def test_unlabeled_mode(self):
        acts = jnp.asarray(np.random.default_rng(2).normal(size=(100, 32)),
                           jnp.float32)
        s = select_metadata(acts, None, KEY, per_class=False,
                            clusters_per_class=8, pca_components=16,
                            kmeans_iters=5)
        assert s.indices.shape == (8,)

    def test_mode_coverage_on_structured_data(self):
        """Clients with clustered data: every mode contributes a rep."""
        rng = np.random.default_rng(3)
        modes = rng.normal(0, 5, (4, 24)).astype(np.float32)
        which = rng.integers(0, 4, 400)
        acts = modes[which] + rng.normal(0, .2, (400, 24)).astype(np.float32)
        s = select_metadata(jnp.asarray(acts), None, KEY, per_class=False,
                            clusters_per_class=4, pca_components=8,
                            kmeans_iters=15)
        sel_modes = set(which[np.asarray(s.indices)])
        assert len(sel_modes) == 4   # one representative per true mode


class TestFusedEngineIdentity:
    """The fused single-pass engine must reproduce the seed implementation
    (``select_metadata_reference``) selection-for-selection."""

    def test_single_pass_equals_seed_reference(self):
        for seed in range(3):
            rng_ = np.random.default_rng(seed)
            acts = jnp.asarray(rng_.normal(size=(300, 6, 6, 4)), jnp.float32)
            labels = jnp.asarray(rng_.integers(0, 6, 300))
            key = jax.random.PRNGKey(seed)
            kw = dict(num_classes=6, clusters_per_class=5,
                      pca_components=24, kmeans_iters=10)
            a = select_metadata(acts, labels, key, **kw)
            b = select_metadata_reference(acts, labels, key, **kw)
            np.testing.assert_array_equal(np.asarray(a.indices),
                                          np.asarray(b.indices))
            np.testing.assert_array_equal(np.asarray(a.valid),
                                          np.asarray(b.valid))

    def test_unlabeled_mode_equals_seed_reference(self):
        acts = jnp.asarray(np.random.default_rng(1).normal(size=(150, 40)),
                           jnp.float32)
        kw = dict(per_class=False, clusters_per_class=8, pca_components=16,
                  kmeans_iters=8)
        a = select_metadata(acts, None, KEY, **kw)
        b = select_metadata_reference(acts, None, KEY, **kw)
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))

    def test_pallas_path_matches_jnp_path(self):
        """use_pallas=True routes init, Lloyd and representatives through
        the fused kernel (interpret mode on CPU) — same selections."""
        acts, labels = structured_acts(0, n=300)
        kw = dict(num_classes=4, clusters_per_class=5, pca_components=16,
                  kmeans_iters=6)
        a = select_metadata(acts, labels, KEY, **kw)
        b = select_metadata(acts, labels, KEY, use_pallas=True, **kw)
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))
        np.testing.assert_array_equal(np.asarray(a.valid),
                                      np.asarray(b.valid))

    def test_randomized_pca_matches_on_structured_maps(self):
        """On decaying-spectrum maps the range-finder PCA spans the same
        subspace, and selections are rotation-invariant within it."""
        for seed in range(3):
            acts, labels = structured_acts(seed)
            key = jax.random.PRNGKey(seed)
            kw = dict(num_classes=4, clusters_per_class=5,
                      pca_components=16, kmeans_iters=10)
            a = select_metadata(acts, labels, key, pca_solver="randomized",
                                **kw)
            b = select_metadata_reference(acts, labels, key, **kw)
            np.testing.assert_array_equal(np.asarray(a.indices),
                                          np.asarray(b.indices))

    def test_batched_vmap_equals_sequential_loop(self):
        """select_metadata_batched over stacked clients == looping clients
        through select_metadata one at a time."""
        B = 4
        cohort = [structured_acts(s) for s in range(B)]
        acts = jnp.stack([a for a, _ in cohort])
        labels = jnp.stack([l for _, l in cohort])
        keys = jax.random.split(KEY, B)
        kw = dict(num_classes=4, clusters_per_class=5, pca_components=16,
                  kmeans_iters=8)
        batched = select_metadata_batched(acts, labels, keys, **kw)
        for i in range(B):
            one = select_metadata(acts[i], labels[i], keys[i], **kw)
            np.testing.assert_array_equal(np.asarray(batched.indices[i]),
                                          np.asarray(one.indices))
            np.testing.assert_array_equal(np.asarray(batched.valid[i]),
                                          np.asarray(one.valid))

    def test_early_exit_matches_full_sweep_budget(self):
        """Lloyd early exit is bit-identical to running the full budget:
        more iterations past convergence change nothing."""
        acts, labels = structured_acts(7)
        kw = dict(num_classes=4, clusters_per_class=3, pca_components=8)
        a = select_metadata(acts, labels, KEY, kmeans_iters=25, **kw)
        b = select_metadata(acts, labels, KEY, kmeans_iters=100, **kw)
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))


class TestRandomizedPCA:
    def test_subspace_matches_exact_on_decaying_spectrum(self):
        acts, _ = structured_acts(0, n=300)
        flat = acts.reshape(300, -1)
        ex = pca_fit(flat, 16)
        rd = pca_fit(flat, 16, solver="randomized")
        p1 = np.asarray(ex.components.T @ ex.components)
        p2 = np.asarray(rd.components.T @ rd.components)
        assert np.abs(p1 - p2).max() < 1e-2
        np.testing.assert_allclose(np.asarray(rd.explained),
                                   np.asarray(ex.explained), rtol=1e-2)

    def test_components_orthonormal(self):
        acts, _ = structured_acts(1, n=200)
        rd = pca_fit(acts.reshape(200, -1), 12, solver="randomized")
        g = np.asarray(rd.components @ rd.components.T)
        np.testing.assert_allclose(g, np.eye(12), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(8, 60), d=st.integers(2, 30), k=st.integers(1, 6),
       seed=st.integers(0, 2 ** 16))
def test_property_kmeans_invariants(n, d, k, seed):
    """For any data: assignments in range, sizes sum to N, own-centroid
    distance is minimal among centroids."""
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)),
                    jnp.float32)
    km = kmeans(x, k, jax.random.PRNGKey(seed), iters=5)
    a = np.asarray(km.assignment)
    assert ((0 <= a) & (a < k)).all()
    assert int(np.asarray(km.cluster_sizes).sum()) == n
    d_all = ((np.asarray(x)[:, None] - np.asarray(km.centroids)[None]) ** 2
             ).sum(-1)
    np.testing.assert_allclose(d_all.min(1), np.asarray(km.distances),
                               rtol=2e-2, atol=2e-2)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 50), d=st.integers(4, 40), p=st.integers(1, 8),
       seed=st.integers(0, 2 ** 16))
def test_property_pca_projection_shrinks(n, d, p, seed):
    """Projection residual never exceeds total variance; explained variances
    are non-negative and descending."""
    p = min(p, n - 1, d)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)),
                    jnp.float32)
    st_ = pca_fit(x, p)
    ev = np.asarray(st_.explained)
    assert (ev >= -1e-4).all()
    assert (np.diff(ev) <= 1e-3).all()
    z = pca_transform(st_, x)
    assert np.isfinite(np.asarray(z)).all()


class TestLloydCarriedStats:
    """The post-loop Lloyd sweep is gone: ``_lloyd_iterate`` carries
    (assign, mindist, sums, counts) through the while_loop and only
    recomputes (lax.cond) on a cap exit — both exits must be bit-identical
    to a fresh ``_lloyd_step`` at the returned centroids."""

    def _problem(self, seed=0, n=120, d=6, k=5):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        lmask = jnp.where(jnp.asarray(rng.random((n, k)) < 0.1), 1e30,
                          0.0).astype(jnp.float32)
        from repro.core.selection import kmeans_init
        c0 = kmeans_init(x, k, KEY)
        return x, c0, lmask

    @pytest.mark.parametrize("iters", [0, 1, 2, 100])
    def test_carried_stats_equal_recompute(self, iters):
        """iters in {0, 1, 2} force cap exits (including the degenerate
        never-ran loop); iters=100 converges and exits early — every case
        must hand back exactly the stats of a final-sweep recompute."""
        from repro.core.selection import _lloyd_iterate, _lloyd_step
        x, c0, lmask = self._problem()
        c, stats, sweeps = _lloyd_iterate(x, c0, lmask, iters, False)
        want = _lloyd_step(x, c, lmask, False)
        for got, ref_ in zip(stats, want):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_))
        # the sweep count is the early-exit telemetry the trace reports:
        # capped runs report the cap, converged runs report fewer
        assert 0 <= int(sweeps) <= iters

    def test_kmeans_non_f32_dtype_traces(self):
        """Regression: the carry's stats0 once hardcoded f32 for mindist/
        counts, so a bf16 feature matrix (which _lloyd_step returns in
        x.dtype) crashed the while_loop with a carry-type mismatch."""
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(40, 4)), jnp.bfloat16)
        km = kmeans(x, 3, KEY, iters=5)
        assert km.assignment.shape == (40,)
        assert km.distances.dtype == jnp.bfloat16

    def test_kmeans_state_unchanged_by_carry(self):
        """kmeans() (which now consumes the carried stats) returns the
        same state as recomputing each field from its centroids."""
        from repro.core.selection import _lloyd_step
        x, _, _ = self._problem(seed=3)
        km = kmeans(x, 4, KEY, iters=25)
        lmask = jnp.zeros((x.shape[0], 4), jnp.float32)
        assign, own, _, sizes = _lloyd_step(x, km.centroids, lmask, False)
        np.testing.assert_array_equal(np.asarray(km.assignment),
                                      np.asarray(assign))
        np.testing.assert_array_equal(np.asarray(km.distances),
                                      np.asarray(own))
        np.testing.assert_array_equal(np.asarray(km.cluster_sizes),
                                      np.asarray(sizes))
