"""Integration tests for the paper's core: split network, FedAvg, metadata
selection, meta-training, compose (Algorithm 1) — on the WRN and a tiny LM.
Includes the pod-engine equalities (chunked streaming, stacked LocalUpdate,
distributed rounds) and the LocalUpdate epoch-shuffle regression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig, get_config, get_wrn_config
from repro.core import fedavg as fa
from repro.core import distributed as dist
from repro.core.compose import evaluate
from repro.core.meta_training import meta_train
from repro.core.rounds import (epoch_permutations, local_batches, run_round,
                               select_for_clients)
from repro.data import (SyntheticImageDataset, SyntheticTokenDataset,
                        partition_k_shards)
from repro.models.transformer import make_split_lm
from repro.models.wrn import make_split_wrn

KEY = jax.random.PRNGKey(0)


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _rounds_identical(a, b):
    assert a.metadata_count == b.metadata_count
    assert a.client_losses == b.client_losses
    assert _trees_equal(a.global_params, b.global_params)
    assert _trees_equal(a.composed_params, b.composed_params)


@pytest.fixture(scope="module")
def wrn():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    params = model.init(KEY)
    return cfg, model, params


class TestSplitMerge:
    def test_wrn_split_roundtrip(self, wrn):
        _, model, params = wrn
        lower, upper = model.split(params)
        merged = model.merge(lower, upper)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(merged)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_wrn_lower_upper_equals_full(self, wrn):
        cfg, model, params = wrn
        x = jax.random.normal(KEY, (4, cfg.image_size, cfg.image_size, 3))
        full = model.apply(params, x)
        acts = model.apply_lower(params, x)
        # paper §4.1: activation maps after group 1 keep spatial dims
        assert acts.shape == (4, cfg.image_size, cfg.image_size, 16)
        two_stage = model.apply_upper(params, acts)
        np.testing.assert_allclose(np.asarray(full), np.asarray(two_stage),
                                   atol=1e-5)

    def test_lm_split_roundtrip_and_equivalence(self):
        cfg = get_config("llama3.2-1b").reduced()
        model, lm = make_split_lm(cfg)
        params = model.init(KEY)
        lower, upper = model.split(params)
        toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        full = model.apply(params, toks)
        acts = model.apply_lower(params, toks)
        logits = model.apply_upper(params, acts)
        np.testing.assert_allclose(np.asarray(full), np.asarray(logits),
                                   rtol=1e-4, atol=1e-4)


class TestFedAvg:
    def test_weight_average_eq2(self, wrn):
        _, model, params = wrn
        ps = [jax.tree.map(lambda x: x + i, params) for i in range(3)]
        avg = fa.weight_average(ps)
        for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b) + 1.0,
                                       atol=1e-5)

    def test_weighted_average(self, wrn):
        _, model, params = wrn
        ps = [jax.tree.map(jnp.zeros_like, params),
              jax.tree.map(jnp.ones_like, params)]
        avg = fa.weight_average(ps, weights=[1, 3])
        assert abs(float(jax.tree.leaves(avg)[0].mean()) - 0.75) < 1e-6

    def test_stacked_equals_list(self, wrn):
        _, model, params = wrn
        ps = [jax.tree.map(lambda x, i=i: x * i, params) for i in range(4)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
        a = fa.weight_average(ps)
        b = fa.weight_average_stacked(stacked)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6)

    def test_local_update_descends(self, wrn):
        cfg, model, params = wrn
        from repro.optim import sgd
        x = jax.random.normal(KEY, (3, 16, cfg.image_size, cfg.image_size, 3))
        y = jax.random.randint(KEY, (3, 16), 0, 10)
        opt = sgd(0.05)
        _, _, losses = fa.local_update(params, opt, opt.init(params), (x, y),
                                       lambda p, b: model.loss(p, b))
        assert losses.shape == (3,)
        assert float(losses[-1]) < float(losses[0]) + 0.5


class TestMetaTraining:
    def test_meta_train_fits_small_set(self, wrn):
        """The paper's overfitting observation (Fig 2): the upper part can
        drive training loss down on a few hundred maps."""
        cfg, model, params = wrn
        _, upper0 = model.split(params)
        rng = np.random.default_rng(0)
        acts = jnp.asarray(rng.normal(size=(40, cfg.image_size,
                                            cfg.image_size, 16)),
                           jnp.float32)
        ys = jnp.asarray(rng.integers(0, 10, 40))
        upper, losses = meta_train(upper0, model.upper_loss, acts, ys,
                                   epochs=30, batch_size=20, lr=0.05,
                                   key=KEY)
        assert float(losses[-5:].mean()) < float(losses[:5].mean())

    def test_l2_regularization_shrinks_weights(self, wrn):
        cfg, model, params = wrn
        _, upper0 = model.split(params)
        rng = np.random.default_rng(0)
        acts = jnp.asarray(rng.normal(size=(20, cfg.image_size,
                                            cfg.image_size, 16)), jnp.float32)
        ys = jnp.asarray(rng.integers(0, 10, 20))
        up_l2, _ = meta_train(upper0, model.upper_loss, acts, ys, epochs=20,
                              batch_size=20, lr=0.05, l2=0.01, key=KEY)
        up_0, _ = meta_train(upper0, model.upper_loss, acts, ys, epochs=20,
                             batch_size=20, lr=0.05, l2=0.0, key=KEY)
        n_l2 = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(up_l2))
        n_0 = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(up_0))
        assert n_l2 < n_0


class TestAlgorithm1:
    def test_round_end_to_end(self, wrn):
        cfg, model, params = wrn
        ds = SyntheticImageDataset(400, image_size=cfg.image_size, seed=0)
        clients = partition_k_shards(ds, 3, k_classes=2,
                                     samples_per_client=60)
        flcfg = FLConfig(num_clients=3, clients_per_round=3,
                         local_batch_size=20, pca_components=16,
                         clusters_per_class=3, kmeans_iters=5,
                         meta_epochs=2, meta_batch_size=10)
        _, upper0 = model.split(params)
        res = run_round(model, params, upper0, clients, flcfg, KEY)
        # |D_M| <= clients * classes-per-client * clusters
        assert 0 < res.metadata_count <= 3 * 10 * 3
        assert res.total_samples == 180
        # selection really is a small fraction of the data (the paper's point)
        assert res.metadata_count / res.total_samples < 0.2
        assert np.isfinite(res.client_losses).all()

    def test_batched_selection_round_equals_sequential(self, wrn):
        """The vmap-over-stacked-clients selection path must reproduce the
        sequential per-client loop bit-for-bit (same keys, same metadata,
        same composed model)."""
        import dataclasses
        cfg, model, params = wrn
        ds = SyntheticImageDataset(300, image_size=cfg.image_size, seed=0)
        clients = partition_k_shards(ds, 3, k_classes=2,
                                     samples_per_client=40)
        flcfg = FLConfig(num_clients=3, clients_per_round=3,
                         local_batch_size=20, pca_components=8,
                         clusters_per_class=3, kmeans_iters=4,
                         meta_epochs=1, meta_batch_size=10,
                         batched_selection=True)
        _, upper0 = model.split(params)
        r1 = run_round(model, params, upper0, clients, flcfg, KEY)
        r2 = run_round(model, params, upper0, clients,
                       dataclasses.replace(flcfg, batched_selection=False),
                       KEY)
        assert r1.metadata_count == r2.metadata_count
        assert r1.client_losses == r2.client_losses
        for a, b in zip(jax.tree.leaves(r1.composed_params),
                        jax.tree.leaves(r2.composed_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_select_for_clients_handles_token_clients(self, wrn):
        """Regression: the cohort size guard used to ``eval_shape`` the
        lower forward with a hardcoded f32 input, so INT token clients (the
        LM generalization) crashed batched selection with a TypeError
        ('Indexer must have integer type') before ever selecting."""
        cfg = get_config("qwen2-0.5b").reduced()
        model, _ = make_split_lm(cfg)
        params = model.init(KEY)
        ds = SyntheticTokenDataset(120, seq_len=16, vocab_size=cfg.vocab_size,
                                   num_classes=4)
        clients = partition_k_shards(ds, 2, k_classes=2,
                                     samples_per_client=40)
        flcfg = FLConfig(pca_components=8, clusters_per_class=2,
                         kmeans_iters=3)
        keys = jax.random.split(KEY, 2)
        pre = select_for_clients(model, params, clients, flcfg, keys, 4)
        assert pre is not None and len(pre) == 2
        sel_acts, sel_y, valid = pre[0][2]
        assert sel_acts.shape[0] == 4 * 2 and valid.shape == (4 * 2,)

    def test_without_selection_uploads_everything(self, wrn):
        cfg, model, params = wrn
        from repro.fl.comms import CommLedger
        from repro.core.rounds import client_round
        ds = SyntheticImageDataset(100, image_size=cfg.image_size, seed=0)
        clients = partition_k_shards(ds, 1, k_classes=2,
                                     samples_per_client=40)
        led_sel, led_all = CommLedger(), CommLedger()
        fl_sel = FLConfig(clusters_per_class=3, pca_components=8,
                          kmeans_iters=3, local_batch_size=20)
        fl_all = FLConfig(use_selection=False, local_batch_size=20)
        client_round(model, params, clients[0], fl_sel, KEY, led_sel, 10)
        client_round(model, params, clients[0], fl_all, KEY, led_all, 10)
        # the paper's communication claim: selection shrinks metadata upload
        assert led_sel.up["metadata"] < led_all.up["metadata"] / 2


class TestEpochShuffling:
    """Regression for the LocalUpdate shuffle bug: ``jnp.tile(perm, E)``
    replayed ONE permutation every local epoch, so multi-epoch SGD saw a
    fixed batch order."""

    def test_fresh_permutation_each_epoch(self):
        perms = np.asarray(epoch_permutations(KEY, 64, 3))
        # each row is a permutation ...
        for e in range(3):
            assert sorted(perms[e]) == list(range(64))
        # ... and multi-epoch batch order actually differs across epochs
        assert not np.array_equal(perms[0], perms[1])
        assert not np.array_equal(perms[1], perms[2])
        # epoch 0 keeps the seed's stream (single-epoch runs unchanged)
        np.testing.assert_array_equal(
            perms[0], np.asarray(jax.random.permutation(KEY, 64)))

    def test_local_batches_differ_across_epochs(self):
        x = jnp.arange(40.0)[:, None]
        y = jnp.arange(40)
        cfg = FLConfig(local_batch_size=10, local_epochs=2)
        bx, _ = local_batches(x, y, KEY, cfg)
        assert bx.shape == (8, 10, 1)          # 2 epochs x 4 steps
        assert not np.array_equal(np.asarray(bx[:4]).ravel(),
                                  np.asarray(bx[4:]).ravel())


class TestDistributedEngine:
    """The pod-scale engine (repro.core.distributed) must be bit-identical
    to the sequential per-client loop: chunked streaming, stacked
    LocalUpdate, and the full distributed round."""

    @pytest.fixture(scope="class")
    def setting(self, wrn):
        cfg, model, params = wrn
        ds = SyntheticImageDataset(300, image_size=cfg.image_size, seed=0)
        clients = partition_k_shards(ds, 4, k_classes=2,
                                     samples_per_client=40)
        flcfg = FLConfig(num_clients=4, clients_per_round=4,
                         local_batch_size=20, pca_components=8,
                         clusters_per_class=3, kmeans_iters=4,
                         meta_epochs=1, meta_batch_size=10, local_epochs=2)
        _, upper0 = model.split(params)
        seq = run_round(model, params, upper0, clients,
                        dataclasses.replace(flcfg, batched_selection=False),
                        KEY)
        return model, params, upper0, clients, flcfg, seq

    def test_stacked_local_update_equals_per_client_loop(self, setting):
        model, params, _, clients, flcfg, _ = setting
        from repro.optim import sgd
        xs, ys = dist.cohort_arrays(clients)
        keys = jax.random.split(KEY, len(clients))
        st_p, st_l = dist.local_update_cohort(model, params, xs, ys, keys,
                                              flcfg)
        opt = sgd(flcfg.local_lr)
        for i in range(len(clients)):
            k_loc = jax.random.split(keys[i])[1]
            bx, by = local_batches(xs[i], ys[i], k_loc, flcfg)
            p, _, losses = fa.local_update(
                params, opt, opt.init(params), (bx, by),
                lambda p_, b: model.loss(p_, b))
            assert float(losses.mean()) == float(st_l[i])
            assert _trees_equal(p, jax.tree.map(lambda a: a[i], st_p))

    def test_distributed_round_equals_sequential(self, setting):
        model, params, upper0, clients, flcfg, seq = setting
        got = run_round(model, params, upper0, clients,
                        dataclasses.replace(flcfg,
                                            distributed_selection=True), KEY)
        _rounds_identical(got, seq)

    def test_chunked_streaming_bitident_across_boundary(self, setting,
                                                        monkeypatch):
        """Both sides of the old MAX_BATCHED_ELEMENTS cliff: a cohort whose
        stack exceeds the budget now STREAMS in chunks (no sequential
        fallback) and still matches the one-stack and sequential paths
        bit-for-bit."""
        import repro.core.rounds as R
        model, params, upper0, clients, flcfg, seq = setting
        keys = jax.random.split(KEY, len(clients) + 1)[:-1]

        # below the boundary: one stack (no chunking)
        pre_stack = select_for_clients(model, params, clients, flcfg, keys,
                                       10)
        assert pre_stack is not None
        one_stack = run_round(model, params, upper0, clients, flcfg, KEY)
        _rounds_identical(one_stack, seq)

        # shrink the budget so this same cohort crosses the boundary:
        # selection must keep returning (chunked), not fall back to None
        monkeypatch.setattr(R, "MAX_BATCHED_ELEMENTS", 1 << 18)
        x_shape = clients[0].data.x.shape
        assert dist.auto_chunk_size(model, params, x_shape, jnp.float32,
                                    len(clients)) > 0
        pre_chunk = select_for_clients(model, params, clients, flcfg, keys,
                                       10)
        assert pre_chunk is not None
        for a, b in zip(pre_stack, pre_chunk):
            for ma, mb in zip(a[2], b[2]):   # (sel_acts, sel_y, valid)
                assert np.array_equal(np.asarray(ma), np.asarray(mb))
        chunked = run_round(model, params, upper0, clients, flcfg, KEY)
        _rounds_identical(chunked, seq)
        # and through the distributed engine as well
        chunked_dist = run_round(
            model, params, upper0, clients,
            dataclasses.replace(flcfg, distributed_selection=True), KEY)
        _rounds_identical(chunked_dist, seq)

        # the escape hatch survives: when even the RAW INPUT stack exceeds
        # the budget (chunking can't help — the engine must hold it), both
        # paths fall back to the sequential per-client loop, which never
        # stacks, and still match
        monkeypatch.setattr(R, "MAX_BATCHED_ELEMENTS", 1 << 10)
        assert not dist.cohort_inputs_fit(clients)
        assert select_for_clients(model, params, clients, flcfg, keys,
                                  10) is None
        tiny = run_round(model, params, upper0, clients,
                         dataclasses.replace(flcfg,
                                             distributed_selection=True),
                         KEY)
        _rounds_identical(tiny, seq)

    def test_explicit_chunk_size_knob(self, setting):
        model, params, upper0, clients, flcfg, seq = setting
        got = run_round(model, params, upper0, clients,
                        dataclasses.replace(flcfg, selection_chunk_size=3),
                        KEY)
        _rounds_identical(got, seq)
