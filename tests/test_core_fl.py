"""Integration tests for the paper's core: split network, FedAvg, metadata
selection, meta-training, compose (Algorithm 1) — on the WRN and a tiny LM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig, get_config, get_wrn_config
from repro.core import fedavg as fa
from repro.core.compose import evaluate
from repro.core.meta_training import meta_train
from repro.core.rounds import run_round
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.models.transformer import make_split_lm
from repro.models.wrn import make_split_wrn

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def wrn():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    params = model.init(KEY)
    return cfg, model, params


class TestSplitMerge:
    def test_wrn_split_roundtrip(self, wrn):
        _, model, params = wrn
        lower, upper = model.split(params)
        merged = model.merge(lower, upper)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(merged)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_wrn_lower_upper_equals_full(self, wrn):
        cfg, model, params = wrn
        x = jax.random.normal(KEY, (4, cfg.image_size, cfg.image_size, 3))
        full = model.apply(params, x)
        acts = model.apply_lower(params, x)
        # paper §4.1: activation maps after group 1 keep spatial dims
        assert acts.shape == (4, cfg.image_size, cfg.image_size, 16)
        two_stage = model.apply_upper(params, acts)
        np.testing.assert_allclose(np.asarray(full), np.asarray(two_stage),
                                   atol=1e-5)

    def test_lm_split_roundtrip_and_equivalence(self):
        cfg = get_config("llama3.2-1b").reduced()
        model, lm = make_split_lm(cfg)
        params = model.init(KEY)
        lower, upper = model.split(params)
        toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        full = model.apply(params, toks)
        acts = model.apply_lower(params, toks)
        logits = model.apply_upper(params, acts)
        np.testing.assert_allclose(np.asarray(full), np.asarray(logits),
                                   rtol=1e-4, atol=1e-4)


class TestFedAvg:
    def test_weight_average_eq2(self, wrn):
        _, model, params = wrn
        ps = [jax.tree.map(lambda x: x + i, params) for i in range(3)]
        avg = fa.weight_average(ps)
        for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b) + 1.0,
                                       atol=1e-5)

    def test_weighted_average(self, wrn):
        _, model, params = wrn
        ps = [jax.tree.map(jnp.zeros_like, params),
              jax.tree.map(jnp.ones_like, params)]
        avg = fa.weight_average(ps, weights=[1, 3])
        assert abs(float(jax.tree.leaves(avg)[0].mean()) - 0.75) < 1e-6

    def test_stacked_equals_list(self, wrn):
        _, model, params = wrn
        ps = [jax.tree.map(lambda x, i=i: x * i, params) for i in range(4)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
        a = fa.weight_average(ps)
        b = fa.weight_average_stacked(stacked)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6)

    def test_local_update_descends(self, wrn):
        cfg, model, params = wrn
        from repro.optim import sgd
        x = jax.random.normal(KEY, (3, 16, cfg.image_size, cfg.image_size, 3))
        y = jax.random.randint(KEY, (3, 16), 0, 10)
        opt = sgd(0.05)
        _, _, losses = fa.local_update(params, opt, opt.init(params), (x, y),
                                       lambda p, b: model.loss(p, b))
        assert losses.shape == (3,)
        assert float(losses[-1]) < float(losses[0]) + 0.5


class TestMetaTraining:
    def test_meta_train_fits_small_set(self, wrn):
        """The paper's overfitting observation (Fig 2): the upper part can
        drive training loss down on a few hundred maps."""
        cfg, model, params = wrn
        _, upper0 = model.split(params)
        rng = np.random.default_rng(0)
        acts = jnp.asarray(rng.normal(size=(40, cfg.image_size,
                                            cfg.image_size, 16)),
                           jnp.float32)
        ys = jnp.asarray(rng.integers(0, 10, 40))
        upper, losses = meta_train(upper0, model.upper_loss, acts, ys,
                                   epochs=30, batch_size=20, lr=0.05,
                                   key=KEY)
        assert float(losses[-5:].mean()) < float(losses[:5].mean())

    def test_l2_regularization_shrinks_weights(self, wrn):
        cfg, model, params = wrn
        _, upper0 = model.split(params)
        rng = np.random.default_rng(0)
        acts = jnp.asarray(rng.normal(size=(20, cfg.image_size,
                                            cfg.image_size, 16)), jnp.float32)
        ys = jnp.asarray(rng.integers(0, 10, 20))
        up_l2, _ = meta_train(upper0, model.upper_loss, acts, ys, epochs=20,
                              batch_size=20, lr=0.05, l2=0.01, key=KEY)
        up_0, _ = meta_train(upper0, model.upper_loss, acts, ys, epochs=20,
                             batch_size=20, lr=0.05, l2=0.0, key=KEY)
        n_l2 = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(up_l2))
        n_0 = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(up_0))
        assert n_l2 < n_0


class TestAlgorithm1:
    def test_round_end_to_end(self, wrn):
        cfg, model, params = wrn
        ds = SyntheticImageDataset(400, image_size=cfg.image_size, seed=0)
        clients = partition_k_shards(ds, 3, k_classes=2,
                                     samples_per_client=60)
        flcfg = FLConfig(num_clients=3, clients_per_round=3,
                         local_batch_size=20, pca_components=16,
                         clusters_per_class=3, kmeans_iters=5,
                         meta_epochs=2, meta_batch_size=10)
        _, upper0 = model.split(params)
        res = run_round(model, params, upper0, clients, flcfg, KEY)
        # |D_M| <= clients * classes-per-client * clusters
        assert 0 < res.metadata_count <= 3 * 10 * 3
        assert res.total_samples == 180
        # selection really is a small fraction of the data (the paper's point)
        assert res.metadata_count / res.total_samples < 0.2
        assert np.isfinite(res.client_losses).all()

    def test_batched_selection_round_equals_sequential(self, wrn):
        """The vmap-over-stacked-clients selection path must reproduce the
        sequential per-client loop bit-for-bit (same keys, same metadata,
        same composed model)."""
        import dataclasses
        cfg, model, params = wrn
        ds = SyntheticImageDataset(300, image_size=cfg.image_size, seed=0)
        clients = partition_k_shards(ds, 3, k_classes=2,
                                     samples_per_client=40)
        flcfg = FLConfig(num_clients=3, clients_per_round=3,
                         local_batch_size=20, pca_components=8,
                         clusters_per_class=3, kmeans_iters=4,
                         meta_epochs=1, meta_batch_size=10,
                         batched_selection=True)
        _, upper0 = model.split(params)
        r1 = run_round(model, params, upper0, clients, flcfg, KEY)
        r2 = run_round(model, params, upper0, clients,
                       dataclasses.replace(flcfg, batched_selection=False),
                       KEY)
        assert r1.metadata_count == r2.metadata_count
        assert r1.client_losses == r2.client_losses
        for a, b in zip(jax.tree.leaves(r1.composed_params),
                        jax.tree.leaves(r2.composed_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_without_selection_uploads_everything(self, wrn):
        cfg, model, params = wrn
        from repro.fl.comms import CommLedger
        from repro.core.rounds import client_round
        ds = SyntheticImageDataset(100, image_size=cfg.image_size, seed=0)
        clients = partition_k_shards(ds, 1, k_classes=2,
                                     samples_per_client=40)
        led_sel, led_all = CommLedger(), CommLedger()
        fl_sel = FLConfig(clusters_per_class=3, pca_components=8,
                          kmeans_iters=3, local_batch_size=20)
        fl_all = FLConfig(use_selection=False, local_batch_size=20)
        client_round(model, params, clients[0], fl_sel, KEY, led_sel, 10)
        client_round(model, params, clients[0], fl_all, KEY, led_all, 10)
        # the paper's communication claim: selection shrinks metadata upload
        assert led_sel.up["metadata"] < led_all.up["metadata"] / 2
