"""Observability tests: trace JSONL schema round-trip, the diff/summarize
CLI exit codes, bit-identity of traced vs untraced zero-fault runs
(weights AND ledger), selection-sketch regression against
``select_metadata``, chaos-trace fault counters vs channel totals, the
MeteredLedger bridge, and a loose tracing-overhead smoke guard."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import FLConfig, get_wrn_config
from repro.core.selection import select_metadata
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.comms import CommLedger
from repro.fl.faults import FaultPlan
from repro.fl.server import FLServer
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn
from repro.obs.__main__ import main as obs_cli
from repro.obs.timing import Timing, monotonic, timeit

NUM_CLASSES, CLUSTERS, ROUNDS = 4, 2, 2
FL_KW = dict(num_clients=3, clients_per_round=3, local_epochs=1,
             local_batch_size=20, local_lr=0.1, pca_components=8,
             clusters_per_class=CLUSTERS, kmeans_iters=4, meta_epochs=2,
             meta_batch_size=8, meta_lr=0.05)


# ------------------------------------------------------------------ units

class TestTiming:
    def test_monotonic_is_monotonic(self):
        a = monotonic()
        b = monotonic()
        assert b >= a

    def test_timeit_returns_timing_and_output(self):
        t = timeit(lambda x: x + 1, 41, iters=3)
        assert isinstance(t, Timing)
        assert t.out == 42 and t.seconds >= 0.0

    def test_timeit_reduce_min_and_errors(self):
        assert timeit(lambda: 7, iters=2, reduce="min").out == 7
        with pytest.raises(ValueError):
            timeit(lambda: 0, iters=0)
        with pytest.raises(ValueError):
            timeit(lambda: 0, reduce="median")


class TestMetrics:
    def test_registry_create_on_first_use(self):
        m = obs.MetricsRegistry()
        m.counter("a").inc()
        m.counter("a").inc(2)
        m.gauge("g").set(1.5)
        m.histogram("h").observe(3.0)
        snap = m.snapshot()
        assert snap["counters"]["a"] == 3
        assert snap["gauges"]["g"] == 1.5
        assert snap["histograms"]["h"]["count"] == 1

    def test_null_metrics_are_inert_singletons(self):
        n = obs.NULL_METRICS
        assert n.counter("x") is n.counter("y")
        n.counter("x").inc(5)
        assert n.counter("x").value == 0
        assert n.snapshot()["counters"] == {}

    def test_metered_ledger_matches_plain_and_mirrors(self):
        plain = CommLedger()
        tr = obs.Tracer()
        metered = obs.MeteredLedger(tr)
        for led in (plain, metered):
            led.upload("metadata", 100)
            led.upload("metadata", 50, frames=2)
            led.download("weights", 400)
        assert metered.summary() == plain.summary()
        snap = tr.metrics.snapshot()["counters"]
        assert snap["ledger.up.metadata.bytes"] == 150
        assert snap["ledger.up.metadata.frames"] == 3
        assert snap["ledger.down.weights.bytes"] == 400
        # no span was open: bytes land in the unattributed bucket
        assert tr.unattributed == {"up/metadata": 150, "down/weights": 400}

    def test_charges_attribute_to_open_span(self):
        tr = obs.Tracer()
        led = obs.MeteredLedger(tr)
        with obs.use_tracer(tr):
            with obs.span("round"):
                with obs.span("select"):
                    led.upload("metadata", 123)
        assert not tr.unattributed
        assert tr.attributed_bytes() == {"up/metadata": 123}
        sel = [s for s in tr.spans if s.name == "select"][0]
        assert sel.bytes == {"up/metadata": 123}


class TestTracer:
    def _tiny_trace(self):
        tr = obs.Tracer(meta={"seed": 0})
        with obs.use_tracer(tr):
            with obs.span("round", round=0):
                with obs.span("select") as sp:
                    sp.set(selected=4)
                obs.event("selection_sketch", client=1, selected=4)
                obs.inc("fault.retransmits", 2)
        return tr

    def test_nested_spans_and_paths(self):
        tr = self._tiny_trace()
        assert [s.name for s in tr.spans] == ["select", "round"]
        recs = tr.to_records()
        assert recs[0]["schema"] == obs.SCHEMA

    def test_jsonl_round_trip(self, tmp_path):
        tr = self._tiny_trace()
        p = tmp_path / "t.jsonl"
        tr.write_jsonl(str(p))
        loaded = obs.load_trace(str(p))
        assert loaded["header"]["meta"] == {"seed": 0}
        assert obs.span_paths(loaded) == {
            "round": {"count": 1, "bytes": 0},
            "round/select": {"count": 1, "bytes": 0}}
        assert loaded["events"][0]["name"] == "selection_sketch"
        assert loaded["metrics"]["snapshot"]["counters"][
            "fault.retransmits"] == 2

    def test_load_rejects_bad_schema_and_bad_json(self, tmp_path):
        p = tmp_path / "bad.jsonl"
        p.write_text('{"type": "header", "schema": "other/v9"}\n')
        with pytest.raises(obs.TraceError):
            obs.load_trace(str(p))
        p.write_text("not json\n")
        with pytest.raises(obs.TraceError):
            obs.load_trace(str(p))
        p.write_text("")
        with pytest.raises(obs.TraceError):
            obs.load_trace(str(p))

    def test_chrome_export_shapes(self, tmp_path):
        tr = self._tiny_trace()
        p = tmp_path / "t.jsonl"
        tr.write_jsonl(str(p))
        doc = obs.to_chrome(obs.load_trace(str(p)))
        phs = {e["ph"] for e in doc["traceEvents"]}
        assert phs == {"X", "i"}
        assert doc["otherData"]["schema"] == obs.SCHEMA
        assert all(e["ts"] >= 0.0 for e in doc["traceEvents"])

    def test_null_tracer_hooks_are_inert(self):
        # module hooks outside any use_tracer: shared singletons, no state
        sp = obs.span("anything")
        assert sp is obs.NULL_SPAN and not sp.enabled
        assert sp.sync(123) == 123
        obs.event("x")
        obs.inc("c")
        obs.gauge("g", 1.0)
        assert obs.get_tracer() is obs.NULL_TRACER


class TestCLI:
    def _write(self, tmp_path, name, mutate=None):
        tr = obs.Tracer()
        with obs.use_tracer(tr):
            with obs.span("round", round=0):
                obs.event("tick")
        if mutate:
            mutate(tr)
        p = tmp_path / name
        tr.write_jsonl(str(p))
        return str(p)

    def test_summarize_ok(self, tmp_path, capsys):
        p = self._write(tmp_path, "a.jsonl")
        assert obs_cli(["summarize", p]) == 0
        out = capsys.readouterr().out
        assert obs.SCHEMA in out and "round" in out

    def test_diff_identical_is_zero(self, tmp_path):
        a = self._write(tmp_path, "a.jsonl")
        b = self._write(tmp_path, "b.jsonl")
        assert obs_cli(["diff", a, b]) == 0

    def test_diff_structural_change_is_one(self, tmp_path):
        a = self._write(tmp_path, "a.jsonl")

        def extra_span(tr):
            with obs.use_tracer(tr):
                with obs.span("eval"):
                    pass
        b = self._write(tmp_path, "b.jsonl", mutate=extra_span)
        assert obs_cli(["diff", a, b]) == 1

    def test_unreadable_or_malformed_is_two(self, tmp_path):
        a = self._write(tmp_path, "a.jsonl")
        with pytest.raises(SystemExit) as e:
            obs_cli(["diff", a, str(tmp_path / "missing.jsonl")])
        assert e.value.code == 2
        bad = tmp_path / "bad.jsonl"
        bad.write_text("garbage\n")
        with pytest.raises(SystemExit) as e:
            obs_cli(["summarize", str(bad)])
        assert e.value.code == 2
        with pytest.raises(SystemExit) as e:
            obs_cli(["no-such-command"])
        assert e.value.code == 2

    def test_export_chrome_writes_json(self, tmp_path):
        a = self._write(tmp_path, "a.jsonl")
        out = tmp_path / "chrome.json"
        assert obs_cli(["export-chrome", a, str(out)]) == 0
        doc = json.loads(out.read_text())
        assert doc["traceEvents"]


# ------------------------------------------------------- end-to-end runs

@pytest.fixture(scope="module")
def setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(150, image_size=cfg.image_size,
                                  num_classes=NUM_CLASSES,
                                  modes_per_class=2, noise=0.25, seed=0)
    test = SyntheticImageDataset(60, image_size=cfg.image_size,
                                 num_classes=NUM_CLASSES,
                                 modes_per_class=2, noise=0.25, seed=1)
    clients = partition_k_shards(train, 3, k_classes=2,
                                 samples_per_client=40, seed=0)
    return model, clients, test


@pytest.fixture(scope="module")
def runs(setting):
    """One untraced + one traced run of the same seed (untraced first, so
    it pays compile and the loose overhead guard is conservative)."""
    model, clients, test = setting
    out = {}
    for name, on in (("off", False), ("on", True)):
        sim = FLSimulation(model, clients, test,
                           FLConfig(**FL_KW, observability=on), seed=0)
        t0 = monotonic()
        res = sim.run(rounds=ROUNDS)
        out[name] = (sim, res, monotonic() - t0)
    return out


class TestTracedRunFidelity:
    def test_bit_identical_weights_and_ledger(self, runs):
        (s0, r0, _), (s1, r1, _) = runs["off"], runs["on"]
        for a, b in zip(jax.tree.leaves(s0.server.global_params),
                        jax.tree.leaves(s1.server.global_params)):
            assert bool((np.asarray(a) == np.asarray(b)).all())
        assert r0.comm == r1.comm

    def test_result_timing_fields_gate_on_observability(self, runs):
        (_, r0, _), (_, r1, _) = runs["off"], runs["on"]
        assert r0.round_wall_s is None and r0.phase_wall_s is None
        assert len(r1.round_wall_s) == ROUNDS
        assert len(r1.phase_wall_s) == ROUNDS
        for phases in r1.phase_wall_s:
            assert {"broadcast", "cohort", "aggregate"} <= set(phases)
            assert all(v >= 0.0 for v in phases.values())

    def test_every_ledger_byte_attributed(self, runs):
        sim, _, _ = runs["on"]
        att = sim.tracer.attributed_bytes()
        up = sum(v for k, v in att.items() if k.startswith("up/"))
        down = sum(v for k, v in att.items() if k.startswith("down/"))
        assert up == sum(sim.server.ledger.up.values())
        assert down == sum(sim.server.ledger.down.values())
        assert not sim.tracer.unattributed

    def test_span_tree_covers_round_phases(self, runs):
        sim, _, _ = runs["on"]
        names = {s.name for s in sim.tracer.spans}
        assert {"round", "broadcast", "cohort", "client", "select",
                "encode", "decode", "local_update", "aggregate",
                "meta_train", "eval"} <= names

    def test_select_spans_carry_lloyd_iters(self, runs):
        sim, _, _ = runs["on"]
        sels = [s for s in sim.tracer.spans if s.name == "select"]
        assert sels
        for s in sels:
            assert s.attrs.get("lloyd_iters", 0) >= 1
            assert 0.0 <= s.attrs["selected_fraction"] <= 1.0

    def test_overhead_smoke_guard(self, runs):
        # loose: tracing must not blow up the run (the tight <=3% claim
        # is BENCH_obs.json's, measured best-of with warmup); the traced
        # run here even has warm caches, so 1.5x catches only pathology
        (_, _, t_off), (_, _, t_on) = runs["off"], runs["on"]
        assert t_on <= t_off * 1.5 + 1.0

    def test_trace_round_trips_and_diffs_clean(self, runs, tmp_path):
        sim, _, _ = runs["on"]
        p = tmp_path / "run.jsonl"
        sim.tracer.write_jsonl(str(p))
        loaded = obs.load_trace(str(p))
        assert len(loaded["spans"]) == len(sim.tracer.spans)
        assert obs_cli(["diff", str(p), str(p)]) == 0


class TestSelectionSketch:
    def test_sketch_count_and_shape(self, runs):
        sim, _, _ = runs["on"]
        sk = [e for e in sim.tracer.events
              if e["name"] == "selection_sketch"]
        assert len(sk) == 3 * ROUNDS          # clients x rounds
        for e in sk:
            occ = np.asarray(e["attrs"]["occupancy"])
            assert occ.shape == (NUM_CLASSES, CLUSTERS)
            assert occ.sum() == e["attrs"]["selected"]
            assert 0.0 <= e["attrs"]["selected_fraction"] <= 1.0

    def test_sketch_matches_select_metadata(self, runs, setting):
        """Regression: the trace's occupancy bitmap IS ``select_metadata``'s
        valid mask for that (round, client) — re-derive round 0's keys the
        way the simulation does and recompute client 0's selection."""
        model, clients, _ = setting
        sim, _, _ = runs["on"]
        cfg = FLConfig(**FL_KW, observability=True)
        key = jax.random.PRNGKey(0)
        k_init, key = jax.random.split(key)
        params = model.init(k_init)
        key, k_round, k_sample = jax.random.split(key, 3)
        idx = FLServer(model, params, model.split(params)[1],
                       cfg).sample_clients(len(clients), k_sample)
        keys = jax.random.split(k_round, len(idx))
        i0 = int(idx[0])
        k_sel, _ = jax.random.split(keys[0])
        c = clients[i0]
        acts = model.apply_lower(params, jnp.asarray(c.data.x))
        sel = select_metadata(acts, jnp.asarray(c.data.y), k_sel,
                              num_classes=NUM_CLASSES,
                              clusters_per_class=CLUSTERS,
                              pca_components=cfg.pca_components,
                              kmeans_iters=cfg.kmeans_iters)
        want = np.asarray(sel.valid).astype(int).reshape(NUM_CLASSES,
                                                         CLUSTERS)
        by_id = {s.span_id: s for s in sim.tracer.spans}

        def round_of(ev):
            sp = by_id[ev["parent"]]
            while "round" not in sp.attrs:
                sp = by_id[sp.parent_id]
            return sp.attrs["round"]

        ev = [e for e in sim.tracer.events
              if e["name"] == "selection_sketch" and round_of(e) == 0
              and e["attrs"]["client"] == i0]
        assert len(ev) == 1
        assert (np.asarray(ev[0]["attrs"]["occupancy"]) == want).all()


class TestChaosTrace:
    def test_trace_counters_match_channel_totals(self, setting):
        model, clients, test = setting
        plan = FaultPlan(bitflip_rate=0.4, truncate_rate=0.2,
                         duplicate_rate=0.2, max_retries=2)
        sim = FLSimulation(model, clients, test,
                           FLConfig(**FL_KW, observability=True,
                                    transport_checksum=True),
                           seed=0, fault_plan=plan, fault_seed=3)
        res = sim.run(rounds=ROUNDS)
        tr = sim.tracer
        counters = tr.metrics.snapshot()["counters"]
        ch = sim.channel
        assert ch.total_injected_corruptions > 0   # the plan actually bit
        assert counters["fault.injected_corruptions"] == \
            ch.total_injected_corruptions
        detected = sum(1 for e in tr.events
                       if e["name"] == "fault.corrupt_detected")
        assert detected == sum(res.corruptions_detected)
        assert counters.get("fault.retransmits", 0) == sum(res.retransmits)
        # CRC on: every injected corruption is detected or lost, never
        # silently consumed — mirrored in the trace
        assert counters.get("fault.silent_corruption", 0) == 0
        assert not tr.unattributed
