"""flcheck (repro.analysis) — rule fixtures, baseline workflow, repo gate.

The three acceptance fixtures re-introduce historical bugs and assert the
exact rule ID fires: the PR 1 ``keys[-1]`` server-key aliasing (RNG003),
an uncharged frame send (LED001), and a misaligned Pallas BlockSpec
(PAL001).  The repo gate runs the real scan against the checked-in
``analysis_baseline.json`` exactly like CI does.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import run_analysis, core
from repro.analysis.selftest import run_self_test

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def scan(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    return run_analysis([str(tmp_path)], root=str(tmp_path))


def rules(findings):
    return {f.rule for f in findings}


# --------------------------------------------------------------- acceptance

def test_pr1_keys_minus_one_bug_is_flagged(tmp_path):
    # the exact shape of the PR 1 bug: the cohort consumes the whole split
    # array while the server aliases its last element
    findings = scan(tmp_path, {"sim.py": """
import jax

def run_round(key, clients, run_cohort, server_round):
    keys = jax.random.split(key, len(clients))
    outs = run_cohort(clients, keys)
    k_server = keys[-1]
    return outs, server_round(k_server)
"""})
    assert "RNG003" in rules(findings)
    (f,) = [f for f in findings if f.rule == "RNG003"]
    assert f.line == 7 and "keys[-1]" in f.message


def test_fixed_disjoint_slice_pattern_is_clean(tmp_path):
    # the post-fix pattern from repro.core.rounds: disjoint slices
    findings = scan(tmp_path, {"sim.py": """
import jax

def run_round(key, clients, run_cohort, server_round):
    keys = jax.random.split(key, len(clients) + 1)
    outs = run_cohort(clients, keys[:-1])
    return outs, server_round(keys[-1])
"""})
    assert not rules(findings)


def test_uncharged_channel_send_is_flagged(tmp_path):
    findings = scan(tmp_path, {"chan.py": """
import struct

class UpperUpdate:
    MSG_TYPE = 2

    def encode(self):
        return struct.pack("<I", 0)

    @classmethod
    def decode(cls, wire):
        if len(wire) < 4:
            raise TruncatedFrame("short")
        return cls()

class Channel:
    def send(self, update):
        wire = UpperUpdate().encode()
        self.deliver(wire)
        return wire
"""})
    assert "LED001" in rules(findings)


def test_charged_channel_send_is_clean(tmp_path):
    findings = scan(tmp_path, {"chan.py": """
import struct

class UpperUpdate:
    MSG_TYPE = 2

    def encode(self):
        return struct.pack("<I", 0)

    @classmethod
    def decode(cls, wire):
        if len(wire) < 4:
            raise TruncatedFrame("short")
        return cls()

class Channel:
    def send(self, update):
        wire = UpperUpdate().encode()
        self._deliver(wire)
        return wire

    def _deliver(self, wire):
        self.ledger.upload("weights", len(wire))
"""})
    assert "LED001" not in rules(findings)


def test_misaligned_blockspec_is_flagged(tmp_path):
    findings = scan(tmp_path, {"k.py": """
import jax
from jax.experimental import pallas as pl

def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def op(x):
    return pl.pallas_call(
        _k,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 200), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 200), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
"""})
    assert "PAL001" in rules(findings)


# ------------------------------------------------------------ rule families

def test_self_test_fixtures_all_pass():
    assert run_self_test() == []


def test_rng001_reuse_after_split(tmp_path):
    findings = scan(tmp_path, {"m.py": """
import jax

def f(key):
    keys = jax.random.split(key, 4)
    y = jax.random.normal(key, (2,))
    return keys, y
"""})
    assert rules(findings) == {"RNG001"}


def test_rng_exclusive_early_return_branches_are_clean(tmp_path):
    # the repro.fl.server.sample_clients shape: two draws on exclusive paths
    findings = scan(tmp_path, {"m.py": """
import jax

def sample(key, n, elig):
    if len(elig) == n:
        return jax.random.choice(key, n, (4,))
    return jax.random.choice(key, len(elig), (4,))
"""})
    assert not rules(findings)


def test_rng004_loop_invariant_selection_key(tmp_path):
    # the examples/federated_lm.py bug: one selection key shared by every
    # client in the round loop
    findings = scan(tmp_path, {"m.py": """
import jax

def round_loop(key, clients, select):
    out = []
    for rnd in range(3):
        for c in clients:
            out.append(select(c, jax.random.fold_in(key, rnd)))
    return out
"""})
    assert "RNG004" in rules(findings)


def test_federated_lm_example_derives_per_client_keys():
    # regression for the fix: the example must scan clean (pre-fix it
    # shared jax.random.fold_in(key, rnd) across all clients -> RNG004)
    findings = run_analysis(
        [os.path.join(REPO, "examples", "federated_lm.py")], root=REPO)
    assert not {f.rule for f in findings if f.rule.startswith("RNG")}


def test_pur001_traced_branch_and_is_none_precision(tmp_path):
    findings = scan(tmp_path, {"m.py": """
import jax

@jax.jit
def f(x, labels):
    if labels is None:
        return x
    if x.sum() > 0:
        return x + 1
    return x
"""})
    led = [f for f in findings if f.rule == "PUR001"]
    assert len(led) == 1 and led[0].line == 8


def test_pur_static_argnames_params_are_static(tmp_path):
    findings = scan(tmp_path, {"m.py": """
import functools
import jax

@functools.partial(jax.jit, static_argnames=("block_n",))
def f(x, block_n):
    if block_n > 8:
        return x[:block_n]
    return x
"""})
    assert not rules(findings)


def test_pal002_and_vmem_budget(tmp_path):
    findings = scan(tmp_path, {"m.py": """
import jax
from jax.experimental import pallas as pl

def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def op(x):
    return pl.pallas_call(
        _k,
        grid=(4,),
        in_specs=[pl.BlockSpec((12, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8192, 1024), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
"""})
    assert {"PAL002", "PAL003"} <= rules(findings)


def test_led002_unknown_category(tmp_path):
    findings = scan(tmp_path, {"m.py": """
def charge(ledger, wire):
    ledger.upload("knowledge", len(wire))
"""})
    assert rules(findings) == {"LED002"}


def test_led003_encode_decode_drift(tmp_path):
    findings = scan(tmp_path, {"m.py": """
import struct

class M:
    MSG_TYPE = 5

    def encode(self):
        return struct.pack("<IIB", 1, 2, 3)

    @classmethod
    def decode(cls, wire):
        a, b = struct.unpack_from("<II", wire, 0)
        if a != 1:
            raise FrameError("bad")
        return cls()
"""})
    assert rules(findings) == {"LED003"}


# ------------------------------------------------- suppressions + baseline

def test_reasonless_suppression_is_sup001_and_not_honored(tmp_path):
    directive = "# " + "flcheck: disable=RNG002"
    findings = scan(tmp_path, {"m.py": f"""
import jax

def f(key):
    x = jax.random.normal(key, (4,))
    y = jax.random.uniform(key, (4,))  {directive}
    return x + y
"""})
    assert rules(findings) == {"RNG002", "SUP001"}


def test_suppression_with_reason_is_honored(tmp_path):
    directive = "# " + "flcheck: disable=RNG002 (A/B same-stream check)"
    findings = scan(tmp_path, {"m.py": f"""
import jax

def f(key):
    x = jax.random.normal(key, (4,))
    y = jax.random.uniform(key, (4,))  {directive}
    return x + y
"""})
    assert not rules(findings)


def test_baseline_grandfathers_old_and_flags_new(tmp_path):
    bad = """
import jax

def f(key):
    x = jax.random.normal(key, (4,))
    y = jax.random.uniform(key, (4,))
    return x + y
"""
    (tmp_path / "old.py").write_text(bad)
    first = run_analysis([str(tmp_path)], root=str(tmp_path))
    base = tmp_path / "analysis_baseline.json"
    core.write_baseline(str(base), first, str(tmp_path))

    (tmp_path / "new.py").write_text(bad.replace("(4,)", "(8,)"))
    second = run_analysis([str(tmp_path)], root=str(tmp_path))
    fresh = core.new_findings(second, core.load_baseline(str(base)),
                              str(tmp_path))
    assert {f.path for f in fresh} == {"new.py"}


def test_baseline_fingerprints_survive_line_shifts(tmp_path):
    bad = """import jax

def f(key):
    x = jax.random.normal(key, (4,))
    y = jax.random.uniform(key, (4,))
    return x + y
"""
    (tmp_path / "m.py").write_text(bad)
    first = run_analysis([str(tmp_path)], root=str(tmp_path))
    base = tmp_path / "b.json"
    core.write_baseline(str(base), first, str(tmp_path))

    (tmp_path / "m.py").write_text("# a new leading comment\n\n" + bad)
    shifted = run_analysis([str(tmp_path)], root=str(tmp_path))
    assert shifted and shifted[0].line != first[0].line
    assert core.new_findings(shifted, core.load_baseline(str(base)),
                             str(tmp_path)) == []


# ------------------------------------------------------------- repo gate

def test_repo_scan_is_clean_against_checked_in_baseline():
    findings = run_analysis(["src", "benchmarks"], root=REPO)
    baseline = core.load_baseline(os.path.join(REPO,
                                               "analysis_baseline.json"))
    fresh = core.new_findings(
        [f for f in findings if f.rule != "SUP001"], baseline, REPO)
    assert fresh == [], "\n".join(f.render() for f in fresh)
    assert not [f for f in findings if f.rule == "SUP001"]


def test_cli_exit_codes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "benchmarks",
         "--against-baseline", "analysis_baseline.json"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--self-test"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ------------------------------------------------------------------ OBS001

def test_obs001_clock_outside_obs_flagged(tmp_path):
    findings = scan(tmp_path, {"bench.py": """
import time

def f():
    t0 = time.perf_counter()
    return time.time() - t0
"""})
    assert len([f for f in findings if f.rule == "OBS001"]) == 2


def test_obs001_from_import_and_alias_flagged(tmp_path):
    findings = scan(tmp_path, {"mod.py": """
import time as _t
from time import monotonic

def f():
    return _t.perf_counter() + monotonic()
"""})
    assert len([f for f in findings if f.rule == "OBS001"]) == 2


def test_obs001_obs_package_is_exempt(tmp_path):
    findings = scan(tmp_path, {"obs/timing.py": """
import time

def now():
    return time.perf_counter()
"""})
    assert "OBS001" not in rules(findings)


def test_obs001_span_without_with_flagged(tmp_path):
    findings = scan(tmp_path, {"mod.py": """
from repro import obs

def f():
    sp = obs.span("round")
    sp2 = obs.timed_block("kernel")
    return sp, sp2
"""})
    assert len([f for f in findings if f.rule == "OBS001"]) == 2


def test_obs001_with_span_and_re_match_span_clean(tmp_path):
    findings = scan(tmp_path, {"mod.py": """
import re

from repro import obs

def f(s):
    with obs.span("round") as sp:
        sp.set(n=1)
    m = re.match(r"x+", s)
    return m.span()
"""})
    assert "OBS001" not in rules(findings)
