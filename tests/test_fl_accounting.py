"""Regression tests for the simulator's accounting: the selected-fraction
denominator under partial participation, and the weight-broadcast download
ledger (charged when the cohort is formed, not post-round). Plus the
simulator-level equality of the stacked (distributed) cohort path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.server import FLServer
from repro.fl.simulation import FLSimulation
from repro.models.wrn import make_split_wrn


@pytest.fixture(scope="module")
def setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(400, image_size=cfg.image_size, seed=0)
    test = SyntheticImageDataset(100, image_size=cfg.image_size, seed=1)
    clients = partition_k_shards(train, 4, k_classes=2,
                                 samples_per_client=40)
    return model, clients, test


def _flcfg(**kw):
    base = dict(num_clients=4, clients_per_round=4, local_batch_size=20,
                pca_components=8, clusters_per_class=3, kmeans_iters=4,
                meta_epochs=1, meta_batch_size=10)
    base.update(kw)
    return FLConfig(**base)


class TestSelectedFraction:
    def test_partial_participation_uses_cohort_samples(self, setting):
        """|D_M|/|D_k| must be over the SAMPLED cohort's samples: with 2 of
        4 clients participating, dividing by all clients' samples halves
        the paper's headline fraction."""
        model, clients, test = setting
        sim = FLSimulation(model, clients, test, _flcfg(clients_per_round=2),
                           seed=0)
        res = sim.run(rounds=1)
        assert res.cohort_samples == [2 * 40]
        assert res.comm["total_samples"] == 4 * 40
        assert res.metadata_counts[-1] > 0
        assert res.selected_fraction == (
            res.metadata_counts[-1] / res.cohort_samples[-1])
        # the buggy denominator (all clients) understates the fraction
        assert res.selected_fraction != (
            res.metadata_counts[-1] / res.comm["total_samples"])

    def test_full_participation_unchanged(self, setting):
        model, clients, test = setting
        sim = FLSimulation(model, clients, test, _flcfg(), seed=0)
        res = sim.run(rounds=1)
        assert res.cohort_samples == [4 * 40]
        assert res.selected_fraction == (
            res.metadata_counts[-1] / res.comm["total_samples"])


class TestDownloadLedger:
    def test_broadcast_charged_at_cohort_formation(self, setting):
        """The cohort downloads W_G(t-1) when it is FORMED — before any
        aggregation — and ``aggregate`` charges no download at all (it used
        to charge post-round for however many clients REPORTED BACK, so
        round 0's initial distribution was never counted and each broadcast
        was attributed to the wrong cohort size). Discriminates the pre-fix
        semantics by aggregating FEWER client params (2) than the formed
        cohort (3): the ledger must show exactly the formation-time charge."""
        model, clients, test = setting
        cfg = _flcfg(meta_epochs=1)
        params = model.init(jax.random.PRNGKey(0))
        _, upper0 = model.split(params)
        server = FLServer(model, params, upper0, cfg)
        nbytes = sum(a.size * 4 for a in jax.tree.leaves(params))

        charged = server.broadcast_weights(3)
        assert charged == 3 * nbytes
        assert server.ledger.down["weights"] == 3 * nbytes

        # 2 of the 3 report back (straggler dropped): pre-fix accounting
        # would now add a 2-client charge post-round; fixed accounting
        # leaves the ledger at the formation-time 3-client charge
        rng = np.random.default_rng(0)
        s = model.config.image_size
        acts = jax.numpy.asarray(
            rng.normal(size=(8, s, s, 16)).astype(np.float32))
        ys = jax.numpy.asarray(rng.integers(0, 10, 8))
        valid = jax.numpy.ones((8,), bool)
        server.aggregate([params, params], [(acts, ys, valid)],
                         jax.random.PRNGKey(1))
        assert server.ledger.down["weights"] == 3 * nbytes

        # and over a full simulation: one broadcast per round, each for the
        # formed cohort at the pre-round weights (round 0 included)
        sim = FLSimulation(model, clients, test, cfg, seed=0)
        assert sim.server.ledger.total_down == 0
        res = sim.run(rounds=2)
        assert res.comm["down"]["weights"] == 2 * 4 * nbytes

    def test_round0_distribution_counted(self, setting):
        """After a single round the download ledger holds exactly round 0's
        initial weight distribution to the sampled cohort."""
        model, clients, test = setting
        sim = FLSimulation(model, clients, test,
                           _flcfg(clients_per_round=2), seed=0)
        nbytes = sum(a.size * 4
                     for a in jax.tree.leaves(sim.server.global_params))
        res = sim.run(rounds=1)
        assert res.comm["down"]["weights"] == 2 * nbytes


class TestDistributedSimulatorEquality:
    def test_distributed_cohort_path_matches_sequential(self, setting):
        """FLSimulation with the stacked pod engine reproduces the
        sequential per-client loop bit-for-bit (losses, counts, ledger,
        accuracies) on the same seed."""
        model, clients, test = setting
        r_seq = FLSimulation(model, clients, test, _flcfg(),
                             seed=0).run(rounds=2)
        r_dist = FLSimulation(model, clients, test,
                              _flcfg(distributed_selection=True),
                              seed=0).run(rounds=2)
        assert r_dist.metadata_counts == r_seq.metadata_counts
        assert r_dist.client_loss == r_seq.client_loss
        assert r_dist.test_acc == r_seq.test_acc
        assert r_dist.fedavg_acc == r_seq.fedavg_acc
        assert r_dist.cohort_samples == r_seq.cohort_samples
        for k in ("up", "down"):
            assert r_dist.comm[k] == r_seq.comm[k]
