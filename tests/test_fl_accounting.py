"""Regression tests for the simulator's accounting: the selected-fraction
denominator under partial participation, the weight-broadcast download
ledger (charged when the cohort is formed, not post-round — and at the
exact WeightBroadcast frame size, native dtypes included), and the
deadline/straggler policy. Plus the simulator-level equality of the
stacked (distributed) cohort path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig, get_wrn_config
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.server import FLServer
from repro.fl.simulation import FLSimulation
from repro.fl.transport import WeightBroadcast
from repro.models.wrn import make_split_wrn


@pytest.fixture(scope="module")
def setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(400, image_size=cfg.image_size, seed=0)
    test = SyntheticImageDataset(100, image_size=cfg.image_size, seed=1)
    clients = partition_k_shards(train, 4, k_classes=2,
                                 samples_per_client=40)
    return model, clients, test


def _flcfg(**kw):
    base = dict(num_clients=4, clients_per_round=4, local_batch_size=20,
                pca_components=8, clusters_per_class=3, kmeans_iters=4,
                meta_epochs=1, meta_batch_size=10)
    base.update(kw)
    return FLConfig(**base)


class TestSelectedFraction:
    def test_partial_participation_uses_cohort_samples(self, setting):
        """|D_M|/|D_k| must be over the SAMPLED cohort's samples: with 2 of
        4 clients participating, dividing by all clients' samples halves
        the paper's headline fraction."""
        model, clients, test = setting
        sim = FLSimulation(model, clients, test, _flcfg(clients_per_round=2),
                           seed=0)
        res = sim.run(rounds=1)
        assert res.cohort_samples == [2 * 40]
        assert res.comm["total_samples"] == 4 * 40
        assert res.metadata_counts[-1] > 0
        assert res.selected_fraction == (
            res.metadata_counts[-1] / res.cohort_samples[-1])
        # the buggy denominator (all clients) understates the fraction
        assert res.selected_fraction != (
            res.metadata_counts[-1] / res.comm["total_samples"])

    def test_full_participation_unchanged(self, setting):
        model, clients, test = setting
        sim = FLSimulation(model, clients, test, _flcfg(), seed=0)
        res = sim.run(rounds=1)
        assert res.cohort_samples == [4 * 40]
        assert res.selected_fraction == (
            res.metadata_counts[-1] / res.comm["total_samples"])


class TestDownloadLedger:
    def test_broadcast_charged_at_cohort_formation(self, setting):
        """The cohort downloads W_G(t-1) when it is FORMED — before any
        aggregation — and ``aggregate`` charges no download at all (it used
        to charge post-round for however many clients REPORTED BACK, so
        round 0's initial distribution was never counted and each broadcast
        was attributed to the wrong cohort size). Discriminates the pre-fix
        semantics by aggregating FEWER client params (2) than the formed
        cohort (3): the ledger must show exactly the formation-time charge
        — which since the transport layer is the exact WeightBroadcast
        frame size, not a ``size * 4`` estimate."""
        model, clients, test = setting
        cfg = _flcfg(meta_epochs=1)
        params = model.init(jax.random.PRNGKey(0))
        _, upper0 = model.split(params)
        server = FLServer(model, params, upper0, cfg)
        nbytes = len(WeightBroadcast(params).encode())

        charged = server.broadcast_weights(3)
        assert charged == 3 * nbytes
        assert server.ledger.down["weights"] == 3 * nbytes

        # 2 of the 3 report back (straggler dropped): pre-fix accounting
        # would now add a 2-client charge post-round; fixed accounting
        # leaves the ledger at the formation-time 3-client charge
        rng = np.random.default_rng(0)
        s = model.config.image_size
        acts = jax.numpy.asarray(
            rng.normal(size=(8, s, s, 16)).astype(np.float32))
        ys = jax.numpy.asarray(rng.integers(0, 10, 8))
        valid = jax.numpy.ones((8,), bool)
        server.aggregate([params, params], [(acts, ys, valid)],
                         jax.random.PRNGKey(1))
        assert server.ledger.down["weights"] == 3 * nbytes

        # and over a full simulation: one broadcast per round, each for the
        # formed cohort at the pre-round weights (round 0 included)
        sim = FLSimulation(model, clients, test, cfg, seed=0)
        assert sim.server.ledger.total_down == 0
        res = sim.run(rounds=2)
        assert res.comm["down"]["weights"] == 2 * 4 * nbytes

    def test_round0_distribution_counted(self, setting):
        """After a single round the download ledger holds exactly round 0's
        initial weight distribution to the sampled cohort."""
        model, clients, test = setting
        sim = FLSimulation(model, clients, test,
                           _flcfg(clients_per_round=2), seed=0)
        nbytes = len(WeightBroadcast(sim.server.global_params).encode())
        res = sim.run(rounds=1)
        assert res.comm["down"]["weights"] == 2 * nbytes

    def test_non_f32_params_charged_at_itemsize(self, setting):
        """Regression for the ``size * 4`` estimate: a bf16 model must be
        billed 2 bytes/element (+ framing), not as f32. Pre-fix,
        ``broadcast_weights`` charged exactly ``4 * size`` per client for
        ANY dtype — this asserts the charge tracks dtype itemsize."""
        model, _, _ = setting
        cfg = _flcfg()
        params = model.init(jax.random.PRNGKey(0))
        p16 = jax.tree.map(lambda a: a.astype(jnp.bfloat16), params)
        _, upper0 = model.split(p16)
        server = FLServer(model, p16, upper0, cfg)
        charged = server.broadcast_weights(1)
        size = sum(a.size for a in jax.tree.leaves(p16))
        nbytes = sum(a.size * a.dtype.itemsize
                     for a in jax.tree.leaves(p16))
        # exact frame accounting: payload is itemsize-true ...
        assert charged == len(WeightBroadcast(p16).encode())
        assert nbytes <= charged < nbytes + size  # framing є o(payload)
        # ... and the pre-fix f32 estimate overbills bf16 by ~2x
        assert charged < size * 4


class TestStragglerDeadline:
    """ROADMAP deadline policy: clients whose estimated local time exceeds
    ``FLServer.deadline`` are masked out of WeightAverage instead of
    waited for — and the policy is bit-identical to no-deadline when
    nobody straggles."""

    def test_straggler_masked_out_of_fedavg(self, setting):
        model, clients, test = setting
        cfg = _flcfg()
        speeds = np.array([1.0, 1.0, 1.0, 1e-4])  # client 3 is ~10^4x slower
        sim = FLSimulation(model, clients, test, cfg, seed=0,
                           client_speeds=speeds, deadline=1e3)
        times = [c.local_time(cfg, sim.flops_per_sample)
                 for c in sim.clients]
        assert max(times[:3]) <= 1e3 < times[3]
        res = sim.run(rounds=1)
        assert res.straggler_counts == [1]

        # the straggler's update must NOT have entered Eq. 2: replay round
        # 0's exact sampling + key derivation on a fresh same-seed sim and
        # compare against the mean of the ON-TIME clients' params only
        sim2 = FLSimulation(model, clients, test, cfg, seed=0,
                            client_speeds=speeds)
        _, k_round, k_sample = jax.random.split(sim2.key, 3)
        idx = sim2.server.sample_clients(len(sim2.clients), k_sample)
        keys = jax.random.split(k_round, len(idx))
        cohort = [sim2.clients[int(i)] for i in idx]
        from repro.core.rounds import run_cohort
        cparams, _, _ = run_cohort(
            model, sim2.server.global_params,
            [c.client for c in cohort], cfg, keys,
            sim2.server.ledger, sim2.num_classes)
        from repro.core import fedavg as fa
        cohort_times = [times[int(i)] for i in idx]
        expected = fa.weight_average(
            [p for p, t in zip(cparams, cohort_times) if t <= 1e3])
        got = sim.server.global_params
        for a, b in zip(jax.tree.leaves(expected), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_no_straggler_bitident_to_no_deadline(self, setting):
        model, clients, test = setting
        cfg = _flcfg()
        r_none = FLSimulation(model, clients, test, cfg, seed=0).run(rounds=2)
        r_dl = FLSimulation(model, clients, test, cfg, seed=0,
                            deadline=1e12).run(rounds=2)
        assert r_dl.straggler_counts == [0, 0]
        assert r_dl.client_loss == r_none.client_loss
        assert r_dl.test_acc == r_none.test_acc
        assert r_dl.fedavg_acc == r_none.fedavg_acc
        assert r_dl.comm == r_none.comm

    def test_all_stragglers_degenerates_to_waiting(self, setting):
        """If EVERY client misses the deadline the server cannot drop the
        cohort — the policy degenerates to waiting for all (exact
        unweighted Eq. 2)."""
        model, clients, test = setting
        cfg = _flcfg()
        r_none = FLSimulation(model, clients, test, cfg, seed=0).run(rounds=1)
        r_all = FLSimulation(model, clients, test, cfg, seed=0,
                             deadline=1e-9).run(rounds=1)
        assert r_all.straggler_counts == [0]
        assert r_all.fedavg_acc == r_none.fedavg_acc


class TestDistributedSimulatorEquality:
    def test_distributed_cohort_path_matches_sequential(self, setting):
        """FLSimulation with the stacked pod engine reproduces the
        sequential per-client loop bit-for-bit (losses, counts, ledger,
        accuracies) on the same seed."""
        model, clients, test = setting
        r_seq = FLSimulation(model, clients, test, _flcfg(),
                             seed=0).run(rounds=2)
        r_dist = FLSimulation(model, clients, test,
                              _flcfg(distributed_selection=True),
                              seed=0).run(rounds=2)
        assert r_dist.metadata_counts == r_seq.metadata_counts
        assert r_dist.client_loss == r_seq.client_loss
        assert r_dist.test_acc == r_seq.test_acc
        assert r_dist.fedavg_acc == r_seq.fedavg_acc
        assert r_dist.cohort_samples == r_seq.cohort_samples
        for k in ("up", "down"):
            assert r_dist.comm[k] == r_seq.comm[k]
