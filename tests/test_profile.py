"""Profiling-layer tests: ``profiled_jit`` cost-annotated spans + the
recompilation sentinel (shape-polymorphic signature counting, tracer-off
no-op, inside-trace fallback), the bench run-registry writer
(``write_bench`` -> BENCH json + history JSONL), the noise-aware
regression gate on synthetic trajectories (in-noise pass, injected
regression, claim flip, empty-history bootstrap) and the
``python -m repro.obs regress`` CLI exit codes."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import profile, registry
from repro.obs.__main__ import main as obs_cli
from repro.obs.profile import CostRecord, profiled_jit


@profiled_jit(name="mm_test", static_argnames=("scale",))
def _mm(a, b, scale=1.0):
    return scale * (a @ b)


def _arrays(n=32, m=16):
    rng = np.random.default_rng(0)
    return (jnp.asarray(rng.normal(size=(n, m)), jnp.float32),
            jnp.asarray(rng.normal(size=(m, n)), jnp.float32))


# ---------------------------------------------------------------- profiled_jit

class TestProfiledJit:
    def test_cost_and_utilization_on_span(self):
        a, b = _arrays()
        tr = obs.Tracer()
        with obs.use_tracer(tr):
            with obs.span("select"):
                _mm(a, b)
        sp = next(s for s in tr.spans if s.name == "select")
        assert sp.attrs["flops"] == pytest.approx(2 * 32 * 16 * 32, rel=0.5)
        assert sp.attrs["hbm_bytes"] > 0
        assert sp.attrs["peak_flops"] > 0
        assert 0 < sp.attrs["utilization"]  # dur > 0 on a real run

    def test_sentinel_counts_each_signature_once(self):
        f = profiled_jit(lambda x: x * 2, name="poly")
        tr = obs.Tracer()
        with obs.use_tracer(tr):
            for n in (4, 8, 16):            # three shapes = three compiles
                f(jnp.zeros((n,), jnp.float32))
            for n in (4, 8, 16):            # repeats: no new compiles
                f(jnp.zeros((n,), jnp.float32))
        counters = tr.metrics.snapshot()["counters"]
        assert counters["compile.poly"] == 3
        assert len([e for e in tr.events if e["name"] == "compile"]) == 3

    def test_static_argnames_split_signature(self):
        @profiled_jit(name="mm_static", static_argnames=("scale",))
        def g(a, b, scale=1.0):
            return scale * (a @ b)

        a, b = _arrays()
        tr = obs.Tracer()
        with obs.use_tracer(tr):
            g(a, b, scale=1.0)
            g(a, b, scale=2.0)              # new static value -> recompile
            g(a, b, scale=2.0)              # cached
        assert tr.metrics.snapshot()["counters"]["compile.mm_static"] == 2

    def test_disabled_tracer_is_plain_jit(self):
        a, b = _arrays()
        out = _mm(a, b)
        assert out.shape == (32, 32)        # no tracer: must not raise

    def test_inside_jax_trace_falls_back(self):
        import jax
        f = profiled_jit(lambda x: x + 1, name="inner_fb")
        tr = obs.Tracer()
        with obs.use_tracer(tr):
            jax.vmap(f)(jnp.zeros((3, 4), jnp.float32))
        # the inner call inlines into the outer trace: no sentinel events
        assert "compile.inner_fb" not in tr.metrics.snapshot()["counters"]

    def test_cost_offline(self):
        a, b = _arrays()
        cost = _mm.cost(a, b)
        assert isinstance(cost, CostRecord)
        assert cost.flops >= 2 * 32 * 16 * 32
        assert cost.hbm_bytes > 0

    def test_roofline_terms(self):
        cost = CostRecord(flops=1e12, hbm_bytes=1e9, collective_bytes=0.0)
        peaks = profile.peak_table("cpu")
        terms = profile.roofline(cost, peaks)
        assert set(terms) == {"compute_s", "memory_s", "collective_s",
                              "bound"}
        assert terms["bound"] in ("compute", "memory", "collective")

    def test_record_from_dryrun_roundtrip(self):
        rec = {"cost": {"flops_expanded": 5.0, "bytes_expanded": 7.0},
               "collectives": {"total_bytes": 3.0,
                               "unknown_trip_counts": 1}}
        c = profile.record_from_dryrun(rec)
        assert (c.flops, c.hbm_bytes, c.collective_bytes,
                c.unknown_trip_loops) == (5.0, 7.0, 3.0, 1)


# ---------------------------------------------------------------- registry

def _report(overhead=0.02, rps=1e5, ok=True):
    return {"overhead_frac": overhead, "records_per_sec": rps,
            "nested": {"traced_s": 1.0 + overhead},
            "pairs": [1, 2, 3],
            "claims": {"overhead_leq_3pct": ok}}


class TestRegistry:
    def test_write_bench_writes_json_and_history(self, tmp_path):
        bench = tmp_path / "BENCH_demo.json"
        rec = registry.write_bench(str(bench), _report())
        assert json.loads(bench.read_text())["overhead_frac"] == 0.02
        hist = registry.load_history(
            str(tmp_path / "experiments" / "bench_history.jsonl"))
        assert len(hist) == 1
        assert hist[0]["bench"] == "demo" == rec["bench"]
        assert hist[0]["schema"] == registry.SCHEMA
        assert "git_rev" in hist[0]["fingerprint"]
        assert hist[0]["scalars"]["nested.traced_s"] == pytest.approx(1.02)
        assert hist[0]["claims"] == {"overhead_leq_3pct": True}

    def test_history_appends(self, tmp_path):
        bench = tmp_path / "BENCH_demo.json"
        for _ in range(3):
            registry.write_bench(str(bench), _report())
        hpath = tmp_path / "experiments" / "bench_history.jsonl"
        assert len(registry.load_history(str(hpath))) == 3

    def test_flatten_scalars_skips_bools_and_lists(self):
        flat = registry.flatten_scalars(_report())
        assert "claims.overhead_leq_3pct" not in flat
        assert "pairs" not in flat
        assert flat["overhead_frac"] == 0.02

    def test_load_history_missing_is_empty(self, tmp_path):
        assert registry.load_history(str(tmp_path / "nope.jsonl")) == []

    def test_load_history_malformed_raises(self, tmp_path):
        p = tmp_path / "h.jsonl"
        p.write_text('{"ok": 1}\nnot json\n')
        with pytest.raises(ValueError):
            registry.load_history(str(p))

    def test_bench_name(self):
        assert registry.bench_name("/x/BENCH_selection.json") == "selection"
        assert registry.bench_name("/x/results.json") is None


# ---------------------------------------------------------------- regress gate

def _history(n, overhead=0.02, rps=1e5, jitter=0.001, ok=True):
    return [registry.history_record(
        "demo", _report(overhead + jitter * ((i % 3) - 1),
                        rps * (1 + 0.01 * ((i % 3) - 1)), ok=ok))
        for i in range(n)]


class TestRegressGate:
    def test_in_noise_passes(self):
        rep = registry.regress_report("demo", _report(0.021), _history(6))
        assert rep["failures"] == []
        assert rep["checked"] > 0

    def test_injected_regression_fails_high_bad(self):
        rep = registry.regress_report("demo", _report(overhead=0.5),
                                      _history(6))
        assert any("overhead_frac" in f for f in rep["failures"])

    def test_injected_regression_fails_low_bad(self):
        rep = registry.regress_report("demo", _report(rps=10.0), _history(6))
        assert any("records_per_sec" in f for f in rep["failures"])

    def test_improvement_never_fails(self):
        rep = registry.regress_report(
            "demo", _report(overhead=0.0001, rps=1e9), _history(6))
        assert rep["failures"] == []

    def test_claim_flip_hard_fails(self):
        rep = registry.regress_report("demo", _report(ok=False), _history(6))
        assert any("flipped FALSE" in f for f in rep["failures"])

    def test_claim_never_true_does_not_fail(self):
        rep = registry.regress_report("demo", _report(ok=False),
                                      _history(6, ok=False))
        assert not any("flipped" in f for f in rep["failures"])

    def test_empty_history_bootstraps(self):
        rep = registry.regress_report("demo", _report(), [])
        assert rep["failures"] == []
        assert rep["checked"] == 0
        assert any("bootstrap" in n for n in rep["notes"])

    def test_min_history_gates_nothing_below_threshold(self):
        rep = registry.regress_report("demo", _report(overhead=9.9),
                                      _history(2))
        assert rep["failures"] == []   # 2 < min_history=3: ungated

    def test_other_bench_history_ignored(self):
        other = _history(6)
        for r in other:
            r["bench"] = "unrelated"
        rep = registry.regress_report("demo", _report(overhead=9.9), other)
        assert rep["failures"] == [] and rep["history_points"] == 0


# ---------------------------------------------------------------- regress CLI

def _seed_cli(tmp_path, n=4, **kw):
    bench = tmp_path / "BENCH_demo.json"
    hpath = tmp_path / "experiments" / "bench_history.jsonl"
    hpath.parent.mkdir()
    with hpath.open("w") as f:
        for r in _history(n):
            f.write(json.dumps(r) + "\n")
    bench.write_text(json.dumps(_report(**kw)))
    return str(bench), str(hpath)


class TestRegressCLI:
    def test_clean_exits_zero(self, tmp_path, capsys):
        bench, hist = _seed_cli(tmp_path)
        assert obs_cli(["regress", bench, "--history", hist]) == 0
        assert "demo" in capsys.readouterr().out

    def test_regression_exits_one(self, tmp_path, capsys):
        bench, hist = _seed_cli(tmp_path, overhead=5.0)
        assert obs_cli(["regress", bench, "--history", hist]) == 1
        assert "FAIL" in capsys.readouterr().out

    def test_missing_bench_exits_two(self, tmp_path):
        _, hist = _seed_cli(tmp_path)
        assert obs_cli(["regress", str(tmp_path / "BENCH_gone.json"),
                        "--history", hist]) == 2

    def test_non_bench_filename_exits_two(self, tmp_path):
        bench, hist = _seed_cli(tmp_path)
        other = tmp_path / "results.json"
        other.write_text("{}")
        assert obs_cli(["regress", str(other), "--history", hist]) == 2

    def test_malformed_history_exits_two(self, tmp_path):
        bench, hist = _seed_cli(tmp_path)
        with open(hist, "a") as f:
            f.write("not json\n")
        assert obs_cli(["regress", bench, "--history", hist]) == 2

    def test_missing_history_bootstraps_zero(self, tmp_path):
        bench, _ = _seed_cli(tmp_path)
        assert obs_cli(["regress", bench, "--history",
                        str(tmp_path / "none.jsonl")]) == 0
