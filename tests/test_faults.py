"""Fault-tolerant runtime tests: typed frame errors (the pre-fix decoder
crash modes), decoder fuzzing, deterministic fault injection, graceful
partial rounds, retransmit accounting, quarantine, and the zero-fault
bit-identity guarantee across engines."""
import dataclasses
import struct

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import FLConfig, get_wrn_config
from repro.core.rounds import client_round, server_round
from repro.data import SyntheticImageDataset, partition_k_shards
from repro.fl.comms import CommLedger
from repro.fl.faults import (FATE_CRASH_AFTER_SELECT,
                             FATE_CRASH_BEFORE_UPLOAD, FATE_OK, FaultPlan,
                             FaultyChannel)
from repro.fl.server import FLServer
from repro.fl.simulation import FLSimulation
from repro.fl.transport import (Channel, FrameError, SelectedKnowledge,
                                TruncatedFrame, UnknownDtype, UpperUpdate,
                                get_codec)
from repro.fl.transport.messages import HEADER_BYTES, MAGIC, V1, VERSION
from repro.models.wrn import make_split_wrn


@pytest.fixture(scope="module")
def setting():
    cfg = get_wrn_config().reduced()
    model = make_split_wrn(cfg)
    train = SyntheticImageDataset(400, image_size=cfg.image_size, seed=0)
    test = SyntheticImageDataset(100, image_size=cfg.image_size, seed=1)
    clients = partition_k_shards(train, 4, k_classes=2,
                                 samples_per_client=40)
    return model, clients, test


def _flcfg(**kw):
    base = dict(num_clients=4, clients_per_round=4, local_batch_size=20,
                pca_components=8, clusters_per_class=3, kmeans_iters=4,
                meta_epochs=1, meta_batch_size=10)
    base.update(kw)
    return FLConfig(**base)


def _params():
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.float32(2.0)}


def _knowledge_frame(checksum=False, codec="raw_f32"):
    rng = np.random.default_rng(0)
    acts = rng.normal(size=(6, 2, 3)).astype(np.float32)
    labels = np.arange(6, dtype=np.int32)
    valid = np.array([1, 1, 0, 1, 0, 1], bool)
    msg = SelectedKnowledge(acts, labels, valid, get_codec(codec))
    return msg.encode(checksum=checksum), (acts, labels, valid)


class TestFrameErrorRegressions:
    """The three decoder crash modes that used to escape as raw
    struct.error / IndexError / numpy ValueError — each must now be a
    typed FrameError (and FrameError must still be a ValueError, so
    pre-hierarchy callers keep working)."""

    def test_short_wire_is_truncated_frame_not_struct_error(self):
        # pre-fix: struct.error from _HEADER.unpack on a sub-header buffer
        wire = UpperUpdate(_params()).encode()
        for cut in (0, 3, HEADER_BYTES - 1):
            with pytest.raises(TruncatedFrame):
                UpperUpdate.decode(wire[:cut])

    def test_bad_dtype_code_is_unknown_dtype_not_index_error(self):
        # pre-fix: IndexError from _DTYPES[code] on a corrupt dtype byte.
        # Payload = leaf-count u32 then the first leaf's dtype code byte.
        wire = bytearray(UpperUpdate(_params()).encode())
        wire[HEADER_BYTES + 4] = 200
        with pytest.raises(UnknownDtype):
            UpperUpdate.decode(bytes(wire))

    def test_undersized_array_data_is_truncated_frame_not_numpy_error(self):
        # pre-fix: numpy ValueError from frombuffer on a buffer smaller
        # than the dims promise. Handcraft a frame whose header length is
        # consistent but whose one (100,) f32 leaf has only 8 data bytes.
        payload = (struct.pack("<I", 1)               # leaf count
                   + struct.pack("<BB", 0, 1)         # f32, ndim 1
                   + struct.pack("<I", 100)           # dims
                   + b"\x00" * 8)                     # 8 of 400 bytes
        frame = struct.Struct("<4sBBBBI").pack(
            MAGIC, VERSION, UpperUpdate.MSG_TYPE, 0, 0, len(payload)
        ) + payload
        with pytest.raises(TruncatedFrame):
            UpperUpdate.decode(frame)

    def test_frame_errors_are_value_errors(self):
        with pytest.raises(ValueError):
            UpperUpdate.decode(b"FL")


class TestV1Compat:
    def test_v1_frame_still_decodes(self):
        """A version-1 frame (reserved byte, no trailer) must parse under
        the v2 decoder: patch the version byte — layout is otherwise
        identical when no checksum is present."""
        wire, (acts, labels, valid) = _knowledge_frame(checksum=False)
        v1 = bytearray(wire)
        assert v1[4] == VERSION
        v1[4] = V1
        a, l, v = SelectedKnowledge.decode(bytes(v1))
        np.testing.assert_array_equal(np.asarray(l), labels[valid])
        np.testing.assert_allclose(np.asarray(a),
                                   acts[valid].reshape(int(valid.sum()),
                                                       2, 3))

    def test_v1_ignores_flag_bits_v2_rejects_unknown(self):
        from repro.fl.transport import BadVersion
        wire = bytearray(_knowledge_frame(checksum=False)[0])
        wire[7] = 0x80                   # unknown flag bit
        with pytest.raises(BadVersion):
            SelectedKnowledge.decode(bytes(wire))
        wire[4] = V1                     # v1: reserved byte, no meaning
        SelectedKnowledge.decode(bytes(wire))


class TestDecoderFuzz:
    """Property: random byte mutations of a valid frame either decode to
    the ORIGINAL payload or raise a FrameError — never any other
    exception. With checksums on, a successful decode additionally implies
    the payload is bit-exact (no silent wrong payload)."""

    def _mutate(self, wire: bytes, rng) -> bytes:
        buf = bytearray(wire)
        for _ in range(int(rng.integers(1, 5))):
            pos = int(rng.integers(0, len(buf)))
            buf[pos] ^= int(rng.integers(1, 256))
        return bytes(buf)

    def _check(self, wire, mutated, reference_decode, decode, strict):
        try:
            out = decode(mutated)
        except FrameError:
            return
        ok = all(np.array_equal(np.asarray(a), np.asarray(b))
                 for a, b in zip(reference_decode, out))
        if strict:
            assert ok, "checksummed frame decoded to a WRONG payload"

    @settings(max_examples=60)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_mutated_frames_never_crash(self, seed):
        rng = np.random.default_rng(seed)
        for checksum in (False, True):
            for codec in ("raw_f32", "int8"):
                wire, _ = _knowledge_frame(checksum=checksum, codec=codec)
                ref = SelectedKnowledge.decode(wire)
                self._check(wire, self._mutate(wire, rng), ref,
                            SelectedKnowledge.decode, strict=checksum)
            wire = UpperUpdate(_params()).encode(checksum=checksum)
            ref = UpperUpdate.decode(wire)
            self._check(wire, self._mutate(wire, rng), ref,
                        UpperUpdate.decode, strict=checksum)

    @settings(max_examples=40)
    @given(cut=st.integers(min_value=0, max_value=10_000))
    def test_truncations_never_crash(self, cut):
        wire, _ = _knowledge_frame(checksum=True)
        cut = cut % len(wire)
        with pytest.raises(FrameError):
            SelectedKnowledge.decode(wire[:cut])


class TestFaultDeterminism:
    def test_same_seed_same_faults_any_call_order(self):
        """The fault schedule is a function of (seed, round, client,
        stream) — NOT of the order the engine happens to deliver frames
        in, which is what keeps sequential/batched/distributed runs
        identical under injection."""
        plan = FaultPlan(drop_rate=0.25, late_crash_rate=0.15,
                         bitflip_rate=0.3, truncate_rate=0.1,
                         duplicate_rate=0.1)
        p = _params()
        ch1 = FaultyChannel(CommLedger(), plan, seed=9)
        ch2 = FaultyChannel(CommLedger(), plan, seed=9)
        for t in range(3):
            ch1.begin_round(t)
            ch2.begin_round(t)
            ids = list(range(10))
            for cid in ids:                       # forward order
                ch1.upload_update(cid, p)
            for cid in reversed(ids):             # reverse order
                ch2.upload_update(cid, p)
            s1, s2 = ch1.round_stats(), ch2.round_stats()
            # backoff_s is a float accumulator: delivery order changes the
            # summation order, so it is only equal to float addition error.
            assert s1.pop("backoff_s") == pytest.approx(s2.pop("backoff_s"))
            assert s1 == s2
            for cid in ids:
                assert ch1.client_fate(cid) == ch2.client_fate(cid)
                assert (ch1.update_arrived(cid)
                        == ch2.update_arrived(cid))
        assert ch1.ledger.summary() == ch2.ledger.summary()

    def test_fates_partition_by_rate(self):
        plan = FaultPlan(drop_rate=1.0)
        ch = FaultyChannel(CommLedger(), plan, seed=0)
        assert all(ch.client_fate(c) == FATE_CRASH_BEFORE_UPLOAD
                   for c in range(5))
        plan = FaultPlan(late_crash_rate=1.0)
        ch = FaultyChannel(CommLedger(), plan, seed=0)
        assert all(ch.client_fate(c) == FATE_CRASH_AFTER_SELECT
                   for c in range(5))
        ch = FaultyChannel(CommLedger(), FaultPlan(), seed=0)
        assert all(ch.client_fate(c) == FATE_OK for c in range(5))


class TestRetransmitAccounting:
    def test_detected_corruption_charges_retransmit_category(self):
        """Always-truncated wire, budget of 2 retries: attempt 0 bills the
        frame's own category once, both retries bill ``retransmit`` at the
        full frame size, the frame is LOST (arrival False), and the
        summary exposes the overhead."""
        plan = FaultPlan(truncate_rate=1.0, max_retries=2)
        led = CommLedger()
        ch = FaultyChannel(led, plan, seed=0, checksum=True)
        p = _params()
        nbytes = len(UpperUpdate(p).encode(checksum=True))
        assert ch.upload_update(0, p) is False
        assert not ch.update_arrived(0)
        assert led.up["weights"] == nbytes
        assert led.up["retransmit"] == 2 * nbytes
        assert led.summary()["retransmit_up"] == 2 * nbytes
        s = ch.round_stats()
        assert s == {"corruptions_detected": 3, "retransmits": 2,
                     "duplicates": 0, "silent_corruptions": 0,
                     "injected_corruptions": 3, "lost_frames": 1,
                     "backoff_s": pytest.approx(0.05 * (1 + 2))}

    def test_crash_before_upload_charges_nothing(self):
        led = CommLedger()
        ch = FaultyChannel(led, FaultPlan(drop_rate=1.0), seed=0)
        assert ch.upload_update(3, _params()) is False
        acts = np.zeros((2, 3), np.float32)
        assert ch.upload_knowledge(3, acts, np.zeros(2, np.int32),
                                   np.ones(2, bool),
                                   get_codec("raw_f32")) is None
        assert led.total_up == 0

    def test_crash_after_select_delivers_knowledge_only(self):
        led = CommLedger()
        ch = FaultyChannel(led, FaultPlan(late_crash_rate=1.0), seed=0)
        acts = np.zeros((2, 3), np.float32)
        out = ch.upload_knowledge(1, acts, np.zeros(2, np.int32),
                                  np.ones(2, bool), get_codec("raw_f32"))
        assert out is not None
        assert led.up["metadata"] > 0
        assert ch.upload_update(1, _params()) is False
        assert "weights" not in led.up


class TestZeroFaultIdentity:
    def test_zero_plan_ledger_matches_perfect_channel(self):
        ledA, ledB = CommLedger(), CommLedger()
        chA = FaultyChannel(ledA, FaultPlan(), seed=0, checksum=False)
        chB = Channel(ledB, checksum=False)
        p = _params()
        acts = np.random.default_rng(0).normal(size=(4, 5)).astype(
            np.float32)
        for cid in range(3):
            chA.upload_update(cid, p)
            chB.upload_update(cid, p)
            chA.upload_knowledge(cid, acts, np.zeros(4, np.int32),
                                 np.ones(4, bool), get_codec("int8"))
            chB.upload_knowledge(cid, acts, np.zeros(4, np.int32),
                                 np.ones(4, bool), get_codec("int8"))
        chA.broadcast_weights(p, 3)
        chB.broadcast_weights(p, 3)
        assert ledA.summary() == ledB.summary()
        assert chA.round_stats() == chB.round_stats()

    def test_checksum_frames_cost_exactly_4_bytes_more(self):
        p = _params()
        on = Channel(CommLedger(), checksum=True)
        off = Channel(CommLedger(), checksum=False)
        on.upload_update(0, p)
        off.upload_update(0, p)
        assert on.ledger.up["weights"] == off.ledger.up["weights"] + 4

    @pytest.mark.chaos
    def test_simulation_zero_plan_bit_identical(self, setting):
        """A simulation handed an all-zero FaultPlan must be bit-identical
        — accuracy, metadata counts, full ledger — to one with no fault
        layer at all."""
        model, clients, test = setting
        r1 = FLSimulation(model, clients, test, _flcfg(),
                          seed=0).run(rounds=2)
        r2 = FLSimulation(model, clients, test, _flcfg(), seed=0,
                          fault_plan=FaultPlan(), fault_seed=7,
                          quarantine_after=3).run(rounds=2)
        assert r1.test_acc == r2.test_acc
        assert r1.fedavg_acc == r2.fedavg_acc
        assert r1.metadata_counts == r2.metadata_counts
        assert r1.comm == r2.comm
        assert r2.drops == [0, 0]
        assert r2.retransmits == [0, 0]
        assert r2.quarantined == [0, 0]


class TestPartialRounds:
    def test_lost_knowledge_frames_are_skipped(self, setting):
        """server_round aggregates over exactly the metadata that ARRIVED:
        None entries (crashed clients / exhausted retries) don't crash the
        concatenate and don't count."""
        model, clients, test = setting
        cfg = _flcfg()
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        _, upper0 = model.split(params)
        led = CommLedger()
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        p1, m1, _ = client_round(model, params, clients[0], cfg, k1, led,
                                 test.num_classes)
        p2, m2, _ = client_round(model, params, clients[1], cfg, k2, led,
                                 test.num_classes)
        full = server_round(model, params, upper0, [p1, p2], [m1, m2],
                            cfg, key)
        part = server_round(model, params, upper0, [p1, p2], [m1, None],
                            cfg, key)
        assert part.metadata_count == int(np.asarray(m1[2]).sum())
        assert part.metadata_count < full.metadata_count

    def test_nothing_arrived_keeps_global_and_upper(self, setting):
        """The degenerate round — every update lost, every knowledge frame
        lost — must keep W_G(t-1) and W_G^u(0) instead of averaging
        nothing / dividing by zero."""
        model, clients, test = setting
        cfg = _flcfg()
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        _, upper0 = model.split(params)
        res = server_round(model, params, upper0, [params, params],
                           [None, None], cfg, key,
                           fedavg_weights=[0.0, 0.0])
        assert res.metadata_count == 0
        for a, b in zip(jax.tree.leaves(res.global_params),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(res.upper_trained),
                        jax.tree.leaves(upper0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_aggregate_combines_straggler_and_arrival_masks(self, setting):
        model, clients, test = setting
        cfg = _flcfg()
        params = model.init(jax.random.PRNGKey(0))
        _, upper0 = model.split(params)
        srv = FLServer(model, params, upper0, cfg)
        # distinct per-client params so the weighting is observable
        cp = [jax.tree.map(lambda a, i=i: a + np.float32(i), params)
              for i in range(3)]
        key = jax.random.PRNGKey(2)
        rr = srv.aggregate(cp, [None, None, None], key,
                           stragglers=np.array([True, False, False]),
                           arrived=np.array([True, True, False]))
        # only client 1 counts: average == its params exactly
        for a, b in zip(jax.tree.leaves(rr.global_params),
                        jax.tree.leaves(cp[1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))


class TestStragglerMaskUnit:
    """Direct unit coverage of FLServer.straggler_mask, including the
    all-stragglers degenerate path (simulation-level coverage lives in
    test_fl_accounting.py)."""

    def _server(self, deadline):
        return FLServer(None, None, None, _flcfg(), deadline=deadline)

    def test_no_deadline_is_none(self):
        assert self._server(None).straggler_mask([1.0, 99.0]) is None

    def test_nobody_late_is_none(self):
        assert self._server(10.0).straggler_mask([1.0, 2.0]) is None

    def test_everybody_late_degenerates_to_waiting(self):
        assert self._server(1.0).straggler_mask([2.0, 3.0, 4.0]) is None

    def test_some_late_masks_exactly_the_late(self):
        mask = self._server(2.5).straggler_mask([1.0, 3.0, 2.0, 9.0])
        np.testing.assert_array_equal(mask, [False, True, False, True])


class TestQuarantine:
    def _server(self, **kw):
        return FLServer(None, None, None, _flcfg(clients_per_round=3),
                        **kw)

    def test_no_quarantine_keeps_exact_sampling_stream(self):
        srv = self._server(quarantine_after=3)
        key = jax.random.PRNGKey(5)
        expected = np.asarray(
            jax.random.choice(key, 10, (3,), replace=False))
        np.testing.assert_array_equal(srv.sample_clients(10, key),
                                      expected)

    def test_streak_trips_quarantine_and_cooldown_readmits(self):
        srv = self._server(quarantine_after=2, quarantine_cooldown=2)
        srv.round_idx = 1
        srv.record_arrivals([0, 1], [False, True])      # streak 0 -> 1
        assert srv.eligible_clients(4) == [0, 1, 2, 3]
        srv.round_idx = 2
        srv.record_arrivals([0, 1], [False, True])      # streak 2: trip
        assert srv.eligible_clients(4) == [1, 2, 3]
        assert srv.num_quarantined(4) == 1
        srv.round_idx = 3                                # still serving
        assert 0 not in srv.eligible_clients(4)
        srv.round_idx = 4                                # cooldown over
        assert srv.eligible_clients(4) == [0, 1, 2, 3]  # re-admitted
        # sampling over 3 eligible of 4 never picks the quarantined one
        srv.round_idx = 3
        for s in range(5):
            idx = srv.sample_clients(4, jax.random.PRNGKey(s))
            assert 0 not in idx and len(idx) == 3

    def test_arrival_clears_streak_and_quarantine(self):
        srv = self._server(quarantine_after=2, quarantine_cooldown=9)
        srv.record_arrivals([5], [False])
        srv.record_arrivals([5], [False])
        assert srv.num_quarantined(6) == 1
        srv.record_arrivals([5], [True])                # delivered: clear
        assert srv.num_quarantined(6) == 0
        assert srv.fail_streak == {}


@pytest.mark.chaos
class TestChaosSimulation:
    """Small end-to-end chaos runs: the simulator survives injected
    faults, counts them, and the engines agree under the same plan."""

    PLAN = FaultPlan(drop_rate=0.3, late_crash_rate=0.1, bitflip_rate=0.2,
                     truncate_rate=0.1, duplicate_rate=0.05)

    def test_faulty_run_counts_and_recovers(self, setting):
        model, clients, test = setting
        sim = FLSimulation(model, clients, test,
                           _flcfg(transport_checksum=True), seed=0,
                           fault_plan=self.PLAN, fault_seed=3,
                           quarantine_after=2, quarantine_cooldown=2)
        res = sim.run(rounds=4)
        assert len(res.test_acc) == 4 and all(
            np.isfinite(a) for a in res.test_acc)
        assert len(res.drops) == len(res.retransmits) == 4
        assert sum(res.drops) > 0
        assert sum(res.corruptions_detected) > 0
        assert res.comm["retransmit_up"] > 0
        # checksums on: injected corruption is NEVER silently consumed
        assert sim.channel.total_silent_corruptions == 0
        assert (sum(res.corruptions_detected)
                == sim.channel.total_injected_corruptions)

    def test_engines_agree_under_identical_faults(self, setting):
        """Sequential and distributed engines under the SAME FaultPlan
        and seeds: identical accuracy trajectory, fault counters and
        ledger — injected faults are keyed on (round, client), not on
        engine call order."""
        model, clients, test = setting
        runs = []
        for distributed in (False, True):
            sim = FLSimulation(
                model, clients, test,
                _flcfg(transport_checksum=True,
                       distributed_selection=distributed), seed=0,
                fault_plan=self.PLAN, fault_seed=3)
            runs.append(sim.run(rounds=2))
        a, b = runs
        assert a.test_acc == b.test_acc
        assert a.drops == b.drops
        assert a.retransmits == b.retransmits
        assert a.corruptions_detected == b.corruptions_detected
        assert a.comm == b.comm
