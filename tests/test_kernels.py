"""Per-kernel shape/dtype sweeps asserting allclose vs the ref.py oracles
(interpret mode on CPU — the brief's validation contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- kmeans
@pytest.mark.parametrize("n,d,k", [
    (64, 16, 4), (300, 200, 10), (256, 128, 32), (100, 37, 7), (512, 200, 20),
])
def test_kmeans_dist_matches_ref(n, d, k):
    x = _rand(KEY, (n, d), jnp.float32)
    c = _rand(jax.random.PRNGKey(1), (k, d), jnp.float32)
    got = ops.kmeans_pairwise_dist(x, c)
    want = ref.kmeans_pairwise_dist_ref(x, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 128), d=st.integers(1, 64), k=st.integers(1, 16),
       seed=st.integers(0, 999))
def test_kmeans_dist_property(n, d, k, seed):
    kk = jax.random.PRNGKey(seed)
    x = _rand(kk, (n, d), jnp.float32)
    c = _rand(jax.random.fold_in(kk, 1), (k, d), jnp.float32)
    got = np.asarray(ops.kmeans_pairwise_dist(x, c))
    want = np.asarray(ref.kmeans_pairwise_dist_ref(x, c))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
    assert (got > -1e-3).all()            # squared distances non-negative


# ---------------------------------------------------------------- fused lloyd
def _lmask(n, k, seed, num_classes=0, masked_rows=0, empty_clusters=0):
    """Additive mask: optional per-class structure, fully-masked rows,
    and clusters no row may join."""
    r = np.random.default_rng(seed)
    if num_classes > 0:
        labels = r.integers(0, num_classes, n)
        slot_class = np.arange(k) % num_classes
        lm = np.where(labels[:, None] == slot_class[None, :], 0.0, 1e30)
    else:
        lm = np.zeros((n, k))
    if masked_rows:
        lm[r.choice(n, masked_rows, replace=False)] = 1e30
    if empty_clusters:
        lm[:, r.choice(k, empty_clusters, replace=False)] = 1e30
    return jnp.asarray(lm, jnp.float32)


def _ref_lloyd_via_pairwise(x, c, lm):
    """The contract path: kmeans_pairwise_dist_ref + jnp argmin/accumulate."""
    d = ref.kmeans_pairwise_dist_ref(x, c) + lm
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    mind = jnp.min(d, axis=1)
    w = (jnp.min(lm, axis=1) <= 0.0).astype(x.dtype)
    onehot = jax.nn.one_hot(assign, c.shape[0], dtype=x.dtype) * w[:, None]
    return assign, mind, onehot.sum(0), onehot.T @ x


@pytest.mark.parametrize("n,d,k,num_classes,masked_rows,empty_clusters", [
    (256, 128, 32, 0, 0, 0),      # aligned, unmasked
    (256, 128, 16, 4, 10, 2),     # aligned, class mask + dead rows/clusters
    (300, 37, 7, 3, 5, 1),        # non-aligned N/D/K
    (100, 200, 10, 0, 100, 0),    # every row masked
    (512, 64, 100, 10, 0, 0),     # select_metadata's 10x10 slot layout
])
def test_kmeans_lloyd_fused_matches_ref(n, d, k, num_classes, masked_rows,
                                        empty_clusters):
    """The fused kernel must reproduce the pairwise-dist + argmin/accumulate
    path: integer outputs bit-for-bit always; float outputs bit-for-bit when
    D is lane-aligned (identical gemm shapes), else within a few ulp (the
    zero-padded gemm reduces in a different order)."""
    x = _rand(KEY, (n, d), jnp.float32)
    c = _rand(jax.random.PRNGKey(1), (k, d), jnp.float32)
    lm = _lmask(n, k, seed=2, num_classes=num_classes,
                masked_rows=masked_rows, empty_clusters=empty_clusters)
    assign, mind, sums, counts = ops.kmeans_lloyd_step(x, c, lm)
    rassign, rmind, rcounts, rsums = _ref_lloyd_via_pairwise(x, c, lm)
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(rassign))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(rcounts))
    if d % 128 == 0:
        np.testing.assert_array_equal(np.asarray(mind), np.asarray(rmind))
        np.testing.assert_array_equal(np.asarray(sums), np.asarray(rsums))
    else:
        np.testing.assert_allclose(np.asarray(mind), np.asarray(rmind),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums),
                                   rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(64, 300), d=st.integers(1, 96), k=st.integers(1, 24),
       seed=st.integers(0, 999))
def test_kmeans_lloyd_fused_property(n, d, k, seed):
    """For any shape/mask: assignments and counts bit-for-bit, statistics
    within gemm-order tolerance, counts account for every unmasked row."""
    kk = jax.random.PRNGKey(seed)
    x = _rand(kk, (n, d), jnp.float32)
    c = _rand(jax.random.fold_in(kk, 1), (k, d), jnp.float32)
    lm = _lmask(n, k, seed, num_classes=seed % 4,
                masked_rows=seed % 7, empty_clusters=seed % min(k, 3))
    assign, mind, sums, counts = ops.kmeans_lloyd_step(x, c, lm)
    rassign, rmind, rcounts, rsums = _ref_lloyd_via_pairwise(x, c, lm)
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(rassign))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(rcounts))
    np.testing.assert_allclose(np.asarray(mind), np.asarray(rmind),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums),
                               rtol=1e-5, atol=1e-4)
    n_admissible = int((np.asarray(lm).min(1) <= 0).sum())
    assert int(np.asarray(counts).sum()) == n_admissible


def test_kmeans_lloyd_fused_vmap_clients():
    """Batched (vmapped-over-clients) fused step == per-client loop."""
    b, n, d, k = 3, 128, 32, 8
    x = _rand(KEY, (b, n, d), jnp.float32)
    c = _rand(jax.random.PRNGKey(1), (b, k, d), jnp.float32)
    lm = jnp.stack([_lmask(n, k, seed=s, num_classes=2) for s in range(b)])
    batched = jax.vmap(ops.kmeans_lloyd_step)(x, c, lm)
    for i in range(b):
        single = ops.kmeans_lloyd_step(x[i], c[i], lm[i])
        for bt, st_ in zip(batched, single):
            np.testing.assert_array_equal(np.asarray(bt[i]), np.asarray(st_))


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("b,s,h,kv,d,causal,window,dtype", [
    (2, 256, 8, 4, 64, True, 0, jnp.float32),
    (1, 256, 4, 4, 128, True, 64, jnp.float32),
    (2, 128, 8, 2, 32, False, 0, jnp.float32),
    (1, 512, 8, 8, 64, True, 128, jnp.float32),
    (2, 256, 4, 1, 64, True, 0, jnp.bfloat16),    # MQA, bf16
    (1, 384, 6, 2, 96, True, 0, jnp.float32),     # non-pow2 seq + head dim
])
def test_flash_attention_matches_ref(b, s, h, kv, d, causal, window, dtype):
    q = _rand(KEY, (b, s, h, d), dtype)
    k = _rand(jax.random.PRNGKey(1), (b, s, kv, d), dtype)
    v = _rand(jax.random.PRNGKey(2), (b, s, kv, d), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=128, block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_equals_model_chunked_path():
    """kernel == the pure-jnp chunked attention used inside the models."""
    from repro.models.layers import sdpa_chunked
    q = _rand(KEY, (2, 256, 8, 64), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (2, 256, 4, 64), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (2, 256, 4, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, window=32,
                            block_q=128, block_k=128)
    b = sdpa_chunked(q, k, v, causal=True, window=32, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


# ---------------------------------------------------------------- decode
@pytest.mark.parametrize("b,s,h,kv,d,fill,dtype", [
    (2, 512, 8, 4, 64, 256, jnp.float32),
    (1, 300, 4, 2, 128, 300, jnp.float32),
    (4, 1024, 8, 8, 64, 17, jnp.float32),
    (2, 256, 16, 2, 64, 128, jnp.bfloat16),
])
def test_flash_decode_matches_ref(b, s, h, kv, d, fill, dtype):
    q = _rand(KEY, (b, 1, h, d), dtype)
    kc = _rand(jax.random.PRNGKey(1), (b, s, kv, d), dtype)
    vc = _rand(jax.random.PRNGKey(2), (b, s, kv, d), dtype)
    valid = jnp.arange(s)[None, :] < fill
    valid = jnp.broadcast_to(valid, (b, s))
    got = ops.flash_decode(q, kc, vc, valid, block_s=128)
    want = ref.flash_decode_ref(q, kc, vc, valid)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_decode_matches_full_attention_last_token():
    """flash-decode(q_T | K,V up to T) == causal full attention's last row."""
    b, s, h, kv, d = 1, 128, 4, 2, 32
    q = _rand(KEY, (b, s, h, d), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (b, s, kv, d), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (b, s, kv, d), jnp.float32)
    full = ref.flash_attention_ref(q, k, v, causal=True)
    valid = jnp.ones((b, s), bool)
    dec = ops.flash_decode(q[:, -1:], k, v, valid, block_s=64)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-3)


# ---------------------------------------------------------------- flash vjp
@pytest.mark.parametrize("causal,window,kv", [(True, 0, 4), (True, 64, 2),
                                              (False, 0, 8)])
def test_flash_custom_vjp_matches_autodiff(causal, window, kv):
    """sdpa_chunked's hand-written backward (recompute-in-bwd, §Perf H1.4)
    must match autodiff of the direct attention."""
    from repro.models.layers import sdpa_chunked, sdpa_full
    b, s, h, d = 2, 128, 8, 32
    q = _rand(KEY, (b, s, h, d), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (b, s, kv, d), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (b, s, kv, d), jnp.float32)
    f1 = lambda *a: jnp.sum(jnp.cos(sdpa_chunked(
        *a, causal=causal, window=window, chunk=32)))
    f2 = lambda *a: jnp.sum(jnp.cos(sdpa_full(
        *a, causal=causal, window=window)))
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)
