"""Per-assigned-architecture smoke tests: REDUCED variant of each family
(<=2-superblock layers, d_model<=128, <=4 experts), one forward + one train
step + one decode step on CPU; asserts output shapes and no NaNs.
(The FULL configs are exercised only via the dry-run — see launch/dryrun.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.registry import make_lm
from repro.optim import sgd

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, t=16):
    toks = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = jax.random.normal(
            KEY, (b, cfg.num_prefix_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jax.random.normal(
            KEY, (b, cfg.encoder_seq_len, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def models():
    return {}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    lm = make_lm(cfg)
    params = lm.init(KEY)
    batch = _batch(cfg)
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    logits, _, aux = lm.apply(params, batch["tokens"], **extras)
    t_total = 16 + (cfg.num_prefix_tokens if cfg.frontend == "vision_stub"
                    else 0)
    assert logits.shape == (2, t_total, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step_reduces_grad_finite(arch):
    cfg = get_config(arch).reduced()
    lm = make_lm(cfg)
    params = lm.init(KEY)
    batch = _batch(cfg)
    loss_fn = lambda p: lm.loss(p, batch)
    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l0))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    opt = sgd(0.1)
    params2, _ = opt.apply(grads, opt.init(params), params)
    l1 = loss_fn(params2)
    assert np.isfinite(float(l1))
    # a full-batch SGD step on a smooth loss should not explode
    assert float(l1) < float(l0) * 1.5 + 1.0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step_and_cache(arch):
    cfg = get_config(arch).reduced()
    lm = make_lm(cfg)
    params = lm.init(KEY)
    cache = lm.init_cache(batch=2, seq_len=32)
    if cfg.is_encoder_decoder:
        cache["enc_out"] = jax.random.normal(
            KEY, (2, cfg.encoder_seq_len, cfg.d_model)).astype(jnp.bfloat16)
    tok = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    for i in range(3):
        logits, cache, _ = lm.apply(params, tok, mode="decode", cache=cache)
        assert logits.shape == (2, 1, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert int(cache["pos"][0]) == i + 1


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-3b"])
def test_decode_agrees_with_full_forward(arch):
    """Teacher-forcing through decode == full causal forward (same logits)."""
    cfg = get_config(arch).reduced()
    lm = make_lm(cfg)
    params = lm.init(KEY)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    full_logits, _, _ = lm.apply(params, toks)
    cache = lm.init_cache(batch=1, seq_len=16, dtype=jnp.float32)
    outs = []
    for i in range(8):
        lg, cache, _ = lm.apply(params, toks[:, i:i + 1], mode="decode",
                                cache=cache)
        outs.append(np.asarray(lg[0, 0], np.float32))
    dec = np.stack(outs)
    np.testing.assert_allclose(dec, np.asarray(full_logits[0], np.float32),
                               rtol=5e-2, atol=5e-2)
